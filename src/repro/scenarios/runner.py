"""Scenario runner + structured report + invariant checker.

``run_scenario`` builds a fresh broker fleet inside its own VirtualClock,
feeds it the spec's traffic through the streaming WorkflowManager, arms the
ChaosEngine (or not: the no-chaos twin), and emits a ``ScenarioReport`` —
one structured, JSON-serializable record of what happened: task outcomes,
makespan, the injected event log, recovery timing, staging/stream/scale
stats, and the post-shutdown residue checks (stranded blocked tasks, live
retry timers, pending clock deadlines, strict-ledger divergence).

``check_invariants`` is the system-level contract from the ISSUE: zero
failed tasks under adversity, bounded makespan inflation vs the twin, a
clean strict ledger, and nothing stranded after ``shutdown()``.  It returns
a list of violation strings — empty means the system held.

Determinism: ``ScenarioReport.fingerprint()`` hashes the stable identity of
a run — the spec name/seed, task totals and outcomes, and the chaos event
schedule as (t, kind, target) triples.  Identical seed => identical
fingerprint.  (Victim sets of preempt kills and raw makespans can shift
with thread interleaving; they are reported but deliberately OUTSIDE the
fingerprint.)"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Optional

from repro.core.autoscaler import ProviderPool
from repro.core.broker import Hydra
from repro.core.chaos import ChaosEngine
from repro.core.events import EventsDivergence
from repro.core.ledger import LedgerDivergence
from repro.core.managers.workflow import WorkflowManager
from repro.runtime.clock import virtual_time

from repro.scenarios.spec import ScenarioSpec
from repro.scenarios.traffic import build_traffic

FAULT_KINDS = ("site_outage", "link_window", "quarantine_storm", "preempt_kill")
RECOVERY_MARKERS = (
    "rebound:",  # cross-provider re-bind (broker fault path)
    "failover:",  # in-group transparent failover
    "rebind_via_gate",  # input-carrying orphan re-entering the staging gate
    "regate:",  # parked task whose reserved placement target died
    "preempted",  # chaos preempt-kill victim
)


@dataclass
class ScenarioReport:
    name: str
    seed: int
    chaos_enabled: bool
    n_workflows: int = 0
    n_tasks: int = 0
    failed_tasks: int = 0
    unresolved_tasks: int = 0
    failed_workflows: int = 0
    makespan_s: float = 0.0
    first_fault_s: Optional[float] = None
    recovery_s: Optional[float] = None
    recovered_tasks: int = 0
    preempted_tasks: int = 0
    events: list = field(default_factory=list)
    event_schedule: list = field(default_factory=list)  # (t, kind, target)
    staging: dict = field(default_factory=dict)
    stream: dict = field(default_factory=dict)
    scale: dict = field(default_factory=dict)
    kernel: dict = field(default_factory=dict)  # kernel.tune/exec rollup
    chaos_stats: dict = field(default_factory=dict)
    ledger_error: Optional[str] = None
    events_error: Optional[str] = None  # strict event-view divergence
    n_bus_events: int = 0  # broker event-log length (core/events.py)
    events_path: Optional[str] = None  # JSONL dump, when recording was asked
    stranded_blocked: int = 0
    stranded_retry_timers: int = 0
    pending_deadlines: int = 0

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "seed": self.seed,
            "chaos_enabled": self.chaos_enabled,
            "n_workflows": self.n_workflows,
            "n_tasks": self.n_tasks,
            "failed_tasks": self.failed_tasks,
            "unresolved_tasks": self.unresolved_tasks,
            "failed_workflows": self.failed_workflows,
            "makespan_s": round(self.makespan_s, 3),
            "first_fault_s": self.first_fault_s,
            "recovery_s": self.recovery_s,
            "recovered_tasks": self.recovered_tasks,
            "preempted_tasks": self.preempted_tasks,
            "events": self.events,
            "event_schedule": self.event_schedule,
            "staging": self.staging,
            "stream": self.stream,
            "scale": self.scale,
            "kernel": self.kernel,
            "chaos_stats": self.chaos_stats,
            "ledger_error": self.ledger_error,
            "events_error": self.events_error,
            "n_bus_events": self.n_bus_events,
            "events_path": self.events_path,
            "stranded_blocked": self.stranded_blocked,
            "stranded_retry_timers": self.stranded_retry_timers,
            "pending_deadlines": self.pending_deadlines,
            "fingerprint": self.fingerprint(),
        }

    def fingerprint(self) -> str:
        """Stable identity of the run (see module docstring)."""
        ident = {
            "name": self.name,
            "seed": self.seed,
            "chaos_enabled": self.chaos_enabled,
            "n_workflows": self.n_workflows,
            "n_tasks": self.n_tasks,
            "failed_tasks": self.failed_tasks,
            "unresolved_tasks": self.unresolved_tasks,
            "schedule": [
                (round(t, 6), kind, target)
                for t, kind, target in self.event_schedule
            ],
        }
        blob = json.dumps(ident, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:16]


def build_broker(spec: ScenarioSpec) -> Hydra:
    """The spec's fleet as a live broker (call inside an active clock)."""
    h = Hydra(
        policy=spec.policy,
        pod_store="memory",
        streaming=True,
        batch_window=spec.batch_window,
        tasks_per_pod=spec.tasks_per_pod,
        staging_seed=spec.seed,
        site_capacity_mb=spec.site_capacity_mb,
        # write-through stage-out: a whole-site outage must not take an
        # intermediate dataset's last copy with it (core/staging.py)
        staging_mirror_outputs=True,
        # multi-tenant front door: weighted-fair lanes + SLO classes
        tenants=[t.to_core() for t in spec.tenants] or None,
    )
    for p in spec.providers:
        h.register_provider(p.to_core())
    if spec.checkpoint_interval_s is not None:
        h.enable_task_checkpoints(interval_s=spec.checkpoint_interval_s)
    if spec.kernel_autotune:
        # modeled timer: scenario determinism must not hinge on wall-clock
        # sweeps, and the roofline pick is what the dry-run report predicts
        h.enable_kernel_autotune(timer="model", seed=spec.seed)
    if spec.elastic:
        pool = ProviderPool([e.to_core() for e in spec.elastic], seed=spec.seed)
        planner = None
        if spec.market_slo_s is not None:
            from repro.core.market import MarketPlanner

            planner = MarketPlanner(slo_target_s=spec.market_slo_s, seed=spec.seed)
        h.autoscale(pool, tick_s=1.0, planner=planner)
    return h


def run_scenario(
    spec: ScenarioSpec, chaos: bool = True, record_events: Optional[str] = None
) -> ScenarioReport:
    """Execute one spec under a fresh VirtualClock; return the report.

    ``chaos=False`` is the no-chaos twin: identical fleet, traffic, and
    seeds, zero injected events — the makespan baseline the inflation
    invariant compares against.

    ``record_events`` dumps the broker's full event log (core/events.py)
    to that JSONL path once the run quiesces; replay it with
    ``python -m repro.core.events replay <path>`` (docs/OBSERVABILITY.md)."""
    report = ScenarioReport(name=spec.name, seed=spec.seed, chaos_enabled=chaos)
    with virtual_time() as clock:
        h = build_broker(spec)
        if h.autotuner is not None and spec.traffic.serve_kernels:
            # pre-tune the serve lane's kernels at their payload shapes:
            # winners land as pinned ``tune:`` datasets in this registry
            # and one kernel.tune event each on this broker's bus
            from repro.kernels.registry import get_kernel

            for kname in spec.traffic.serve_kernels:
                h.autotuner.tune(kname, get_kernel(kname).tiny_shape, "float32")
        wfs = build_traffic(h.staging.registry, spec.traffic, prefix=spec.name)
        tasks = [t for wf in wfs for t in wf.tasks]
        report.n_workflows = len(wfs)
        report.n_tasks = len(tasks)
        engine: Optional[ChaosEngine] = None
        if chaos and spec.chaos:
            engine = ChaosEngine(
                h, [c.to_core() for c in spec.chaos], seed=spec.seed
            )
        t0 = clock.now()
        if engine is not None:
            engine.arm()
        WorkflowManager(h).run(wfs, wait=True, timeout=spec.timeout_s)
        report.makespan_s = clock.now() - t0

        # -- task outcomes ---------------------------------------------
        for t in tasks:
            if not t.done():
                report.unresolved_tasks += 1
            elif t.cancelled() or t.exception() is not None:
                report.failed_tasks += 1
        report.failed_workflows = sum(1 for wf in wfs if wf.failed)

        # -- chaos timeline + recovery ---------------------------------
        if engine is not None:
            engine.stop()
            report.events = list(engine.log)
            report.event_schedule = engine.planned()
            report.chaos_stats = engine.stats()
            report.preempted_tasks = len(engine.preempted_uids)
            faults = [e["t"] for e in engine.log if e["kind"] in FAULT_KINDS]
            if faults:
                report.first_fault_s = min(faults) - t0
                last_recovered = None
                for t in tasks:
                    touched = any(
                        ev.startswith(RECOVERY_MARKERS)
                        for ev, _ in t.trace.events
                    )
                    if not touched:
                        continue
                    done_at = t.trace.last("exec_done")
                    if done_at is None:
                        continue
                    report.recovered_tasks += 1
                    if last_recovered is None or done_at > last_recovered:
                        last_recovered = done_at
                if last_recovered is not None:
                    report.recovery_s = max(
                        0.0, last_recovered - min(faults)
                    )

        # -- subsystem stats + post-shutdown residue -------------------
        report.staging = h.staging_stats()
        report.stream = h.stream_stats()
        scale = h.scale_stats()
        scale.pop("pending_acquisitions", None)  # not JSON-stable
        report.scale = scale
        report.kernel = {
            "execs": h.kernel_execs,
            "execs_by": dict(h.kernel_execs_by),
            "reps": h.kernel_reps,
            "seconds": round(h.kernel_seconds, 6),
            "tunes": h.autotuner.tunes if h.autotuner is not None else 0,
        }
        report.n_bus_events = len(h.events)
        if record_events is not None:
            h.events.dump_jsonl(record_events)
            report.events_path = record_events
        try:
            h.shutdown(wait=True)
        except LedgerDivergence as exc:
            report.ledger_error = str(exc)
        except EventsDivergence as exc:
            report.events_error = str(exc)
        d = h._dispatcher
        if d is not None:
            report.stranded_blocked = d.stalled_on_staging()
            report.stranded_retry_timers = len(d._retry_timers)
        pending = getattr(clock, "pending_deadlines", None)
        if pending is not None:
            report.pending_deadlines = pending()
    return report


def check_invariants(
    chaos_report: ScenarioReport,
    baseline_report: Optional[ScenarioReport],
    spec: ScenarioSpec,
) -> list[str]:
    """System-level contract under adversity; [] means the system held."""
    violations: list[str] = []
    for rep in (chaos_report, baseline_report):
        if rep is None:
            continue
        tag = "chaos" if rep.chaos_enabled else "baseline"
        if rep.failed_tasks:
            violations.append(f"{tag}: {rep.failed_tasks} task(s) failed")
        if rep.unresolved_tasks:
            violations.append(
                f"{tag}: {rep.unresolved_tasks} task future(s) never resolved"
            )
        if rep.failed_workflows:
            violations.append(f"{tag}: {rep.failed_workflows} workflow(s) failed")
        if rep.ledger_error:
            violations.append(f"{tag}: strict ledger diverged: {rep.ledger_error}")
        if rep.stranded_blocked:
            violations.append(
                f"{tag}: {rep.stranded_blocked} task(s) stranded in the "
                "staging-blocked set after shutdown"
            )
        if rep.stranded_retry_timers:
            violations.append(
                f"{tag}: {rep.stranded_retry_timers} live retry timer(s) "
                "after shutdown"
            )
        if rep.pending_deadlines:
            violations.append(
                f"{tag}: {rep.pending_deadlines} clock deadline(s) still "
                "pending after shutdown"
            )
    if baseline_report is not None and baseline_report.makespan_s > 0:
        inflation = chaos_report.makespan_s / baseline_report.makespan_s
        if inflation > spec.max_makespan_inflation:
            violations.append(
                f"makespan inflation {inflation:.3f}x exceeds the spec bound "
                f"{spec.max_makespan_inflation}x "
                f"({chaos_report.makespan_s:.1f}s vs "
                f"{baseline_report.makespan_s:.1f}s)"
            )
    return violations


def makespan_inflation(
    chaos_report: ScenarioReport, baseline_report: ScenarioReport
) -> float:
    if baseline_report.makespan_s <= 0:
        return float("inf")
    return chaos_report.makespan_s / baseline_report.makespan_s
