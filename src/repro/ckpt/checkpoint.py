"""Sharded checkpointing: save/restore of train state with a manifest,
atomic step directories, async save, and retention.

Layout:
    <dir>/step_000100/
        manifest.json     # step, flat param paths, shapes, dtypes
        arrays.npz        # one entry per flattened leaf
    <dir>/LATEST          # atomic pointer file

On a real multi-pod fleet each host writes its local shards (the DataManager
stages them to the shared store); in this single-process container the full
arrays are written.  The restart path is identical either way: restore() is
driven by the manifest, validated against the model's spec tree.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Optional

import numpy as np

import jax


def _flatten(tree) -> dict[str, Any]:
    flat = {}

    def walk(prefix, t):
        if isinstance(t, dict):
            for k in sorted(t):
                walk(f"{prefix}/{k}" if prefix else str(k), t[k])
        elif isinstance(t, (list, tuple)):
            for i, v in enumerate(t):
                walk(f"{prefix}/{i}", v)
        else:
            flat[prefix] = t

    walk("", tree)
    return flat


def save(ckpt_dir: str, step: int, state_tree, keep: int = 3) -> str:
    """Synchronous checkpoint save.  Returns the step directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(state_tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    manifest = {
        "step": step,
        "leaves": {
            k: {"shape": list(a.shape), "dtype": str(a.dtype)} for k, a in arrays.items()
        },
    }
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _write_latest(ckpt_dir, final)
    _retain(ckpt_dir, keep)
    return final


def _write_latest(ckpt_dir: str, final: str):
    latest = os.path.join(ckpt_dir, "LATEST")
    tmpf = latest + ".tmp"
    with open(tmpf, "w") as f:
        f.write(os.path.basename(final))
    os.replace(tmpf, latest)


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training: save() snapshots to host
    memory synchronously (cheap) and writes in a background thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state_tree):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), state_tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def latest_step(ckpt_dir: str) -> Optional[int]:
    latest = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, like_tree, step: Optional[int] = None, shardings=None):
    """Restore a state tree.  ``like_tree`` provides structure/dtypes.

    Returns (step, state_tree) or raises FileNotFoundError.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    flat_like = _flatten(like_tree)
    missing = set(flat_like) - set(manifest["leaves"])
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]} ...")
    flat_shard = _flatten(shardings) if shardings is not None else None

    leaves, treedef = jax.tree.flatten(like_tree)
    paths = sorted(flat_like)
    out = {}
    for k in paths:
        arr = data[k]
        want = flat_like[k]
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(f"shape mismatch for {k}: {arr.shape} vs {want.shape}")
        arr = arr.astype(want.dtype)
        if flat_shard is not None:
            arr = jax.device_put(arr, flat_shard[k])
        out[k] = arr
    # rebuild in like_tree order
    rebuilt = [out[k] for k in _flatten_order(like_tree)]
    return step, jax.tree.unflatten(treedef, rebuilt)


def _flatten_order(tree) -> list[str]:
    """Paths in jax.tree.flatten leaf order (dict keys sorted = jax order)."""
    order = []

    def walk(prefix, t):
        if isinstance(t, dict):
            for k in sorted(t):
                walk(f"{prefix}/{k}" if prefix else str(k), t[k])
        elif isinstance(t, (list, tuple)):
            for i, v in enumerate(t):
                walk(f"{prefix}/{i}", v)
        else:
            order.append(prefix)

    walk("", tree)
    return order
