"""Sharded checkpointing: save/restore of train state with a manifest,
atomic step directories, async save, and retention.

Layout:
    <dir>/step_000100/
        manifest.json     # step, flat param paths, shapes, dtypes
        arrays.npz        # one entry per flattened leaf
    <dir>/LATEST          # atomic pointer file

On a real multi-pod fleet each host writes its local shards (the DataManager
stages them to the shared store); in this single-process container the full
arrays are written.  The restart path is identical either way: restore() is
driven by the manifest, validated against the model's spec tree.

``TaskCheckpointer`` (bottom of this module) is the broker-facing sibling:
task-level checkpoint/restore where checkpoints are replicated datasets in
the broker's DatasetRegistry, letting a preempt-killed task resume from its
captured ``progress_frac`` on a surviving provider (core/broker.py).
"""
from __future__ import annotations

import json
import math
import os
import shutil
import tempfile
import threading
from typing import Any, Optional

import numpy as np

import jax


def _flatten(tree) -> dict[str, Any]:
    flat = {}

    def walk(prefix, t):
        if isinstance(t, dict):
            for k in sorted(t):
                walk(f"{prefix}/{k}" if prefix else str(k), t[k])
        elif isinstance(t, (list, tuple)):
            for i, v in enumerate(t):
                walk(f"{prefix}/{i}", v)
        else:
            flat[prefix] = t

    walk("", tree)
    return flat


def save(ckpt_dir: str, step: int, state_tree, keep: int = 3) -> str:
    """Synchronous checkpoint save.  Returns the step directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(state_tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    manifest = {
        "step": step,
        "leaves": {
            k: {"shape": list(a.shape), "dtype": str(a.dtype)} for k, a in arrays.items()
        },
    }
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _write_latest(ckpt_dir, final)
    _retain(ckpt_dir, keep)
    return final


def _write_latest(ckpt_dir: str, final: str):
    latest = os.path.join(ckpt_dir, "LATEST")
    tmpf = latest + ".tmp"
    with open(tmpf, "w") as f:
        f.write(os.path.basename(final))
    os.replace(tmpf, latest)


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


class _SaveHandle:
    """Completion handle for ``async_save``: ``wait()`` blocks until the
    scheduled write finished and re-raises any stored error."""

    def __init__(self):
        self._done = threading.Event()
        self._error: Optional[BaseException] = None
        self._path: Optional[str] = None

    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None) -> str:
        if not self._done.wait(timeout):
            raise TimeoutError("async_save did not complete in time")
        if self._error is not None:
            raise self._error
        return self._path


def async_save(
    ckpt_dir: str, step: int, state_tree, keep: int = 3, delay_s: float = 0.0
) -> _SaveHandle:
    """Asynchronous checkpoint save on the shared Clock (what the module
    docstring promises): snapshot the tree to host memory NOW (cheap, so
    the caller may keep mutating device state), schedule the write via
    ``Clock.call_later`` — deterministic under ``virtual_time()`` — and
    return a handle whose ``wait()`` joins the write and re-raises errors.
    """
    from repro.runtime.clock import get_clock

    host_tree = jax.tree.map(lambda x: np.asarray(x), state_tree)
    handle = _SaveHandle()

    def work():
        try:
            handle._path = save(ckpt_dir, step, host_tree, keep)
        except BaseException as e:  # re-raised on wait()
            handle._error = e
        finally:
            handle._done.set()

    get_clock().call_later(delay_s, work)
    return handle


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training: save() snapshots to host
    memory synchronously (cheap) and writes in a background thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state_tree):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), state_tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def latest_step(ckpt_dir: str) -> Optional[int]:
    latest = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, like_tree, step: Optional[int] = None, shardings=None):
    """Restore a state tree.  ``like_tree`` provides structure/dtypes.

    Returns (step, state_tree) or raises FileNotFoundError.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    flat_like = _flatten(like_tree)
    missing = set(flat_like) - set(manifest["leaves"])
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]} ...")
    flat_shard = _flatten(shardings) if shardings is not None else None

    leaves, treedef = jax.tree.flatten(like_tree)
    paths = sorted(flat_like)
    out = {}
    for k in paths:
        arr = data[k]
        want = flat_like[k]
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(f"shape mismatch for {k}: {arr.shape} vs {want.shape}")
        arr = arr.astype(want.dtype)
        if flat_shard is not None:
            arr = jax.device_put(arr, flat_shard[k])
        out[k] = arr
    # rebuild in like_tree order
    rebuilt = [out[k] for k in _flatten_order(like_tree)]
    return step, jax.tree.unflatten(treedef, rebuilt)


class TaskCheckpointer:
    """Task-level checkpoint/restore for the broker (core/broker.py wires
    this via ``Hydra.enable_task_checkpoints``).

    Checkpoints are *replicated datasets*: each preempted task's captured
    progress registers as ``ckpt:<uid>`` in the broker's DatasetRegistry
    with a durable replica in the shared store, and the checkpoint name is
    appended to the task's declared ``inputs``.  The resume therefore
    re-enters through the dispatcher's staging gate like any data-carrying
    task: the TransferEngine stages the checkpoint to whatever surviving
    site the policy picks (placement obeys data gravity), and the shared
    replica survives the death of the site that was running the task.

    The progress model is write-behind: a running task is assumed to have
    durably checkpointed at every ``interval_s`` of executed work, so a
    preemption loses only the tail since the last interval boundary —
    ``lost_s = done_s - floor(done_s / interval_s) * interval_s`` — and
    the resumed task executes only the remaining work
    (``managers/compute.py`` sleeps ``duration * (1 - progress_frac)``).
    Resumes never charge ``Task.max_retries``.
    """

    def __init__(self, registry, events, interval_s: float = 5.0, size_mb: float = 64.0):
        from repro.runtime.clock import get_clock  # noqa: F401 (validated here)

        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        self.registry = registry
        self.events = events
        self.interval_s = interval_s
        self.size_mb = size_mb
        self._lock = threading.Lock()
        # legacy accumulators (HYDRA_EVENTS_CHECK ground truth)
        self.saves = 0
        self.resumes = 0
        self.reexecuted_s = 0.0
        self.preempted_work_s = 0.0

    def eligible(self, task) -> bool:
        """Only work with resumable progress checkpoints: duration-modeled
        sleeps and rep-granular kernel payloads (managers/compute.py
        KernelRuntime advances ``progress_frac`` per completed rep).
        noop/callable/compute tasks restart from zero like before."""
        if task.kind == "kernel":
            return True
        return task.kind == "sleep" and task.duration > 0

    def on_preempt(self, task) -> None:
        """A preempt-style kill landed on ``task`` (state FAILED): capture
        its progress as a checkpoint dataset and mark it resumable.  The
        caller (broker) then resets the task WITHOUT charging a retry."""
        from repro.core.staging import SHARED_SITE
        from repro.runtime.clock import get_clock

        if task.kind == "kernel":
            # rep-granular payloads checkpoint themselves: the KernelRuntime
            # advances progress_frac at every completed-rep boundary, so the
            # current value already IS the last durable checkpoint and only
            # the partial rep in flight is lost (it was never counted done)
            done_s = task.kernel_done_s
            lost_s = 0.0
        else:
            prior_s = task.progress_frac * task.duration
            t0 = task.trace.last("exec_start")
            run_s = 0.0
            if t0 is not None:
                run_s = min(max(0.0, get_clock().now() - t0), task.duration - prior_s)
            done_s = prior_s + run_s
            # last durable interval boundary; never regress below prior progress
            ckpt_s = max(math.floor(done_s / self.interval_s) * self.interval_s, prior_s)
            lost_s = done_s - ckpt_s
            task.progress_frac = min(1.0, ckpt_s / task.duration)
        name = f"ckpt:{task.uid}"
        # durable shared-store replica: survives the executing site's death;
        # the staging gate moves it (via TransferEngine) to the resume site
        self.registry.add(name, self.size_mb, sites=(SHARED_SITE,))
        if task.ckpt_dataset is None:
            task.ckpt_dataset = name
        if name not in task.inputs:
            task.inputs.append(name)
        task.resumes += 1
        task.trace.add(f"ckpt_resume:{task.progress_frac:.3f}")
        with self._lock:
            self.saves += 1
            self.resumes += 1
            self.reexecuted_s += lost_s
            self.preempted_work_s += done_s
            self.events.emit(
                "ckpt.save",
                task=task.uid,
                dataset=name,
                progress=task.progress_frac,
            )
            self.events.emit(
                "ckpt.resume",
                task=task.uid,
                progress=task.progress_frac,
                lost_s=lost_s,
                done_s=done_s,
            )

    def stats(self) -> dict:
        """Log-derived view adapter (legacy accumulators stay as strict-mode
        ground truth); ``reexec_frac`` is exp13's headline recovery metric."""
        self.events.maybe_check()
        view = self.events.view
        reexec = view.get("hydra.ckpt.reexecuted_s")
        preempted = view.get("hydra.ckpt.preempted_work_s")
        return {
            "saves": int(view.get("hydra.ckpt.saves")),
            "resumes": int(view.get("hydra.ckpt.resumes")),
            "reexecuted_s": reexec,
            "preempted_work_s": preempted,
            "reexec_frac": (reexec / preempted) if preempted > 0 else 0.0,
        }


def _flatten_order(tree) -> list[str]:
    """Paths in jax.tree.flatten leaf order (dict keys sorted = jax order)."""
    order = []

    def walk(prefix, t):
        if isinstance(t, dict):
            for k in sorted(t):
                walk(f"{prefix}/{k}" if prefix else str(k), t[k])
        elif isinstance(t, (list, tuple)):
            for i, v in enumerate(t):
                walk(f"{prefix}/{i}", v)
        else:
            order.append(prefix)

    walk("", tree)
    return order
