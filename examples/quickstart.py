"""Quickstart: broker a heterogeneous workload across cloud + HPC pools.

    PYTHONPATH=src python examples/quickstart.py

Shows the four public API classes from the paper (Provider via ProviderSpec,
Service via the broker's managers, Resource, Task), SCPP-vs-MCPP
partitioning, and the OVH/TH/TPT/TTX metrics.
"""
import sys

sys.path.insert(0, "src")

from repro.core import Hydra, ProviderSpec, Resources, Task

# 1. Start the broker (Service Proxy + Provider Proxy inside).
hydra = Hydra(policy="load_aware", pod_store="memory", partitioning="mcpp", tasks_per_pod=32)

# 2. Register providers: two cloud pools + one HPC pilot pool.
hydra.register_provider(ProviderSpec(name="jet2", platform="cloud", concurrency=4))
hydra.register_provider(ProviderSpec(name="aws", platform="cloud", concurrency=4))
hydra.register_provider(
    ProviderSpec(name="bridges2", platform="hpc", connector="pilot", concurrency=8)
)

# 3. A heterogeneous workload: noops (overhead probes), sleeps (work), a
#    python callable, and a JAX train-step "container" task.
tasks = (
    [Task(kind="noop") for _ in range(500)]
    + [Task(kind="sleep", duration=0.005) for _ in range(50)]
    + [Task(kind="callable", fn=lambda: sum(range(1000)))]
    + [
        Task(
            kind="compute",
            arch="llama3-8b",
            step_kind="train",
            resources=Resources(cpus=2, accels=1),
        )
    ]
)

# 4. Submit (bind -> partition -> serialize -> bulk dispatch), then wait.
sub = hydra.submit(tasks)
sub.wait(timeout=300)

# 5. The paper's metrics, derived from traces.
m = sub.metrics()
print(f"states       : {sub.states}")
print(f"OVH          : {m.ovh*1e3:.1f} ms  (phases: { {k: round(v*1e3,1) for k,v in m.phases.items()} } ms)")
print(f"TH           : {m.th:,.0f} tasks/s")
print(f"TPT          : {m.tpt*1e3:.1f} ms")
print(f"TTX          : {m.ttx*1e3:.1f} ms")
print(f"train metrics: {tasks[-1].result()}")

hydra.shutdown()
print("OK")
