"""Serve a small model with batched requests: prefill + autoregressive
decode across three architecture families (KV cache, SSM state, hybrid).

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch.serve import serve

for arch in ("llama3-8b", "falcon-mamba-7b", "recurrentgemma-2b"):
    out = serve(arch, reduced=True, batch=4, prompt_len=32, gen=16, temperature=0.8)
    print(f"{arch:22s} prefill {out['prefill_s']*1e3:7.1f} ms  "
          f"decode {out['decode_s_per_token']*1e3:6.1f} ms/tok  "
          f"{out['tokens_per_s']:7.1f} tok/s")
print("OK")
