"""End-to-end driver: train a ~100M-param llama-style model for a few
hundred steps with checkpoint/restart (assignment deliverable b).

    PYTHONPATH=src python examples/train_lm.py [steps]

Uses the full framework path: config -> Model -> sharding strategy ->
AdamW -> prefetching data pipeline -> async checkpoints.  The model is a
~100M-param member of the llama3 family (same code path as the 8B/405B
configs; only the dimensions differ).
"""
import sys
import tempfile

sys.path.insert(0, "src")

from repro.configs import get_arch
from repro.launch.train import train

steps = int(sys.argv[1]) if len(sys.argv) > 1 else 200

# ~100M params: 12 layers, d_model 768, vocab 32k
arch100m = get_arch("llama3-8b").replace(
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
    d_ff=2048, vocab_size=32000, param_dtype="float32", compute_dtype="float32",
    remat="none",
)
print(f"training {arch100m.param_count()/1e6:.0f}M params for {steps} steps")

from repro.configs.registry import ARCHS

ARCHS["llama3-100m"] = arch100m  # register so the driver can resolve it

with tempfile.TemporaryDirectory() as ckpt_dir:
    out = train(
        "llama3-100m", reduced=False, steps=steps, seq_len=128, global_batch=8,
        peak_lr=6e-4, ckpt_dir=ckpt_dir, ckpt_every=max(steps // 4, 1), log_every=20,
    )

print(f"loss: {out['first_loss']:.3f} -> {out['final_loss']:.3f} over {out['steps']} steps")
assert out["final_loss"] < out["first_loss"], "training must reduce loss"
print("OK")
