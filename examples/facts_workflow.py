"""FACTS sea-level workflow at scale (paper Experiment 4, scaled down).

    PYTHONPATH=src python examples/facts_workflow.py [n_instances]

Runs N concurrent 4-stage FACTS workflow instances (pre-processing ->
fitting -> projecting -> post-processing) across a cloud pool and an HPC
pilot, then prints the ensemble's end-of-century sea-level-rise quantiles.
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import Hydra, ProviderSpec, WorkflowManager
from repro.facts.workflow import make_workflow, result_of

n_instances = int(sys.argv[1]) if len(sys.argv) > 1 else 16

# streaming=True: readiness events from all instances coalesce in the
# broker's micro-batching dispatcher instead of one submit() per frontier
hydra = Hydra(policy="load_aware", pod_store="memory", streaming=True)
hydra.register_provider(ProviderSpec(name="jet2", platform="cloud", concurrency=4))
hydra.register_provider(ProviderSpec(name="aws", platform="cloud", concurrency=4))
hydra.register_provider(
    ProviderSpec(name="bridges2", platform="hpc", connector="pilot", concurrency=8)
)

wfm = WorkflowManager(hydra)
workflows = [make_workflow(hydra.data, i, n_samples=500) for i in range(n_instances)]

t0 = time.perf_counter()
wfm.run(workflows)
ttx = time.perf_counter() - t0

assert all(w.done and not w.failed for w in workflows)
p50s = [result_of(hydra.data, i)["quantiles"]["p50"] for i in range(n_instances)]
print(f"{n_instances} FACTS instances in {ttx:.2f}s "
      f"({4*n_instances} tasks, {4*n_instances/ttx:.1f} tasks/s)")
print(f"median 2100 rise across sites: {np.median(p50s):.0f} mm "
      f"(site spread {np.min(p50s):.0f}..{np.max(p50s):.0f} mm)")
stats = hydra.stream_stats()
print(f"streaming: {stats['batches']} micro-batches, "
      f"{stats['n_submits']} pipeline rounds, {stats['n_pods']} pods")

hydra.shutdown()
print("OK")
