"""Docs lint: in-repo links resolve, and the observability docs cannot
drift from the event taxonomy.

Two checks, both wired into `make docs-check` and the CI lint job:

1. **Links** — every relative markdown link target in the repo's tracked
   `.md` files exists on disk (fragments stripped; `http(s)`/`mailto`
   targets skipped).  A doc that names a file that was moved or renamed
   fails the build instead of rotting.
2. **Taxonomy sync** — the event table in `docs/OBSERVABILITY.md` and the
   `EVENTS` registry in `src/repro/core/events.py` must describe the same
   set of event names, in both directions: an event added to the code
   without a docs row fails, and a documented event the code no longer
   emits fails.
3. **Metric sync** — every derived metric a reducer maintains (the
   ``metrics`` list in each event spec, e.g. ``hydra.cost_dollars`` from
   ``market.spend``) must be mentioned somewhere in
   `docs/OBSERVABILITY.md`, so a new ``market.*``-style event cannot land
   with its metrics undocumented.

Stdlib only; run as ``PYTHONPATH=src python tools/docs_check.py``.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OBSERVABILITY = os.path.join(REPO, "docs", "OBSERVABILITY.md")

# [text](target) — excluding images is unnecessary (targets must exist
# either way); inline code spans are not matched by this shape
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# a taxonomy-table row's first cell: | `subsystem.action` | ...
_EVENT_ROW = re.compile(r"^\|\s*`([a-z_]+\.[a-z_]+)`\s*\|")


def tracked_markdown() -> list[str]:
    out = subprocess.run(
        ["git", "ls-files", "*.md", "**/*.md"],
        cwd=REPO,
        capture_output=True,
        text=True,
        check=True,
    )
    return sorted(set(out.stdout.split()))


def check_links(md_files: list[str]) -> list[str]:
    errors = []
    for rel in md_files:
        path = os.path.join(REPO, rel)
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            target = target.split("#", 1)[0]
            if not target:  # pure fragment: same-file anchor
                continue
            resolved = os.path.normpath(os.path.join(os.path.dirname(path), target))
            if not os.path.exists(resolved):
                errors.append(f"{rel}: broken link -> {m.group(1)}")
    return errors


def check_taxonomy() -> list[str]:
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.core.events import EVENTS

    with open(OBSERVABILITY, encoding="utf-8") as fh:
        documented = {
            m.group(1) for line in fh if (m := _EVENT_ROW.match(line.strip()))
        }
    errors = []
    for name in sorted(set(EVENTS) - documented):
        errors.append(
            f"docs/OBSERVABILITY.md: event `{name}` exists in "
            "core/events.py but has no taxonomy-table row"
        )
    for name in sorted(documented - set(EVENTS)):
        errors.append(
            f"docs/OBSERVABILITY.md: documented event `{name}` does not "
            "exist in core/events.py"
        )
    return errors


def check_metrics() -> list[str]:
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.core.events import EVENTS

    with open(OBSERVABILITY, encoding="utf-8") as fh:
        text = fh.read()
    errors = []
    for name, spec in sorted(EVENTS.items()):
        for metric in spec.metrics:
            if metric not in text:
                errors.append(
                    f"docs/OBSERVABILITY.md: metric `{metric}` (derived "
                    f"from `{name}`) is not documented"
                )
    return errors


def main() -> int:
    md_files = tracked_markdown()
    errors = check_links(md_files) + check_taxonomy() + check_metrics()
    for e in errors:
        print(f"docs-check: {e}", file=sys.stderr)
    if errors:
        print(f"docs-check: {len(errors)} error(s)", file=sys.stderr)
        return 1
    print(f"docs-check: {len(md_files)} markdown files OK, taxonomy in sync")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
