"""Experiment 7: elastic acquisition — weak scaling + the cost of elasticity.

The paper's central claim is concurrent acquisition of cloud and HPC
resources sized to the workload (§1, §4-5).  With the autoscaler
(core/autoscaler.py) the broker can now *grow into* demand, so two protocol
pieces become measurable:

  weak scaling   - fixed work per demanded node (W tasks x d seconds each),
                   demanded node count swept 1 -> 16.  An ideal elastic
                   broker keeps makespan ~constant: each extra unit of work
                   brings its own provider.  Reported: makespan, acquired
                   provider count (must reach the demanded level under
                   sustained pressure), weak-scaling efficiency
                   T(1)/T(n), and node-seconds actually held.

  cost curve     - FIXED total work, elastic (min 1, max 16, paying modeled
                   cloud-startup queue wait) vs statically over-provisioned
                   pools of k = 1..16 providers held for the whole run.
                   Static pools trade node-seconds (cost) for makespan
                   (no queue wait); the elastic run should land near the
                   big-static makespan at a fraction of its node-seconds.

Everything runs under a VirtualClock with a seeded latency RNG: modeled
cloud startup latencies (~30 virtual seconds) cost real milliseconds and
the whole experiment is deterministic.
"""
from __future__ import annotations

import time

from repro.core import Hydra, LaunchSpec, ProviderPool, Task, cloud_startup
from repro.core.provider import ProviderSpec
from repro.runtime.clock import virtual_time

from benchmarks.common import print_rows, write_csv


def _cloud_template(name: str, concurrency: int = 4) -> ProviderSpec:
    return ProviderSpec(name=name, platform="cloud", connector="caas", concurrency=concurrency)


def _run_tasks(h: Hydra, tasks: list[Task], real_timeout_s: float = 120.0) -> tuple[float, float]:
    """Dispatch and wait; returns (virtual makespan, absolute end timestamp).
    Makespan runs first-dispatch -> last exec_done, excluding post-drain
    idle ticks; the absolute end is what node-seconds accounting needs
    (Autoscaler.node_seconds takes a clock timestamp, not a duration)."""
    from repro.runtime.clock import get_clock

    t0 = get_clock().now()
    h.dispatch(tasks)
    deadline = time.monotonic() + real_timeout_s
    while not all(t.done() for t in tasks) and time.monotonic() < deadline:
        time.sleep(0.02)
    assert all(t.done() for t in tasks), "exp7: tasks did not drain"
    assert all(t.exception() is None for t in tasks), "exp7: failed tasks"
    ends = [t.trace.last("exec_done") for t in tasks]
    end = max(e for e in ends if e is not None)
    return end - t0, end


def weak_scaling(
    node_counts=(1, 2, 4, 8, 16),
    tasks_per_node: int = 16,
    task_s: float = 8.0,
    acq_mean_s: float = 30.0,
) -> list[dict]:
    rows = []
    t1 = None
    for n in node_counts:
        with virtual_time():
            h = Hydra(streaming=True, pod_store="memory", batch_window=0.002)
            pool = ProviderPool(
                [
                    LaunchSpec(
                        template=_cloud_template("elastic"),
                        min_instances=1,
                        max_instances=n,
                        latency=cloud_startup(mean_s=acq_mean_s, sigma=0.2),
                    )
                ],
                seed=1234,
            )
            scaler = h.autoscale(
                pool,
                tick_s=1.0,
                warmup_ticks=2,
                cooldown_ticks=4,
                scale_out_pressure=1.2,
                max_concurrent_acquisitions=n,
            )
            tasks = [Task(kind="sleep", duration=task_s) for _ in range(n * tasks_per_node)]
            makespan, end_ts = _run_tasks(h, tasks)
            node_s = scaler.node_seconds(until=end_ts)
            row = {
                "mode": "weak",
                "n_demanded": n,
                "n_acquired": scaler.arrivals,
                "n_tasks": len(tasks),
                "makespan_s": round(makespan, 2),
                "node_seconds": round(node_s, 1),
                "scaled_to_demand": scaler.arrivals >= n,
            }
            h.shutdown(wait=True)
        t1 = t1 if t1 is not None else makespan
        row["weak_efficiency"] = round(t1 / makespan, 3)
        rows.append(row)
    return rows


def cost_curve(
    n_tasks: int = 128,
    task_s: float = 8.0,
    static_counts=(1, 2, 4, 8, 16),
    acq_mean_s: float = 30.0,
) -> list[dict]:
    rows = []
    # statically over-provisioned baselines: k providers held end to end
    for k in static_counts:
        with virtual_time():
            h = Hydra(streaming=True, pod_store="memory", batch_window=0.002)
            for i in range(k):
                h.register_provider(_cloud_template(f"static{i}"))
            tasks = [Task(kind="sleep", duration=task_s) for _ in range(n_tasks)]
            makespan, _ = _run_tasks(h, tasks)
            rows.append(
                {
                    "mode": f"static_{k}",
                    "n_providers": k,
                    "n_tasks": n_tasks,
                    "makespan_s": round(makespan, 2),
                    "node_seconds": round(k * makespan, 1),
                }
            )
            h.shutdown(wait=True)
    # elastic: starts at 1, grows under pressure, pays the queue wait
    with virtual_time():
        h = Hydra(streaming=True, pod_store="memory", batch_window=0.002)
        pool = ProviderPool(
            [
                LaunchSpec(
                    template=_cloud_template("elastic"),
                    min_instances=1,
                    max_instances=max(static_counts),
                    latency=cloud_startup(mean_s=acq_mean_s, sigma=0.2),
                )
            ],
            seed=1234,
        )
        scaler = h.autoscale(
            pool,
            tick_s=1.0,
            warmup_ticks=2,
            cooldown_ticks=4,
            scale_out_pressure=1.2,
            max_concurrent_acquisitions=max(static_counts),
        )
        tasks = [Task(kind="sleep", duration=task_s) for _ in range(n_tasks)]
        makespan, end_ts = _run_tasks(h, tasks)
        rows.append(
            {
                "mode": "elastic",
                "n_providers": scaler.arrivals,
                "n_tasks": n_tasks,
                "makespan_s": round(makespan, 2),
                "node_seconds": round(scaler.node_seconds(until=end_ts), 1),
            }
        )
        h.shutdown(wait=True)
    biggest = rows[len(static_counts) - 1]
    for row in rows:
        row["cost_vs_max_static"] = round(row["node_seconds"] / max(biggest["node_seconds"], 1e-9), 3)
    return rows


def run(weak_nodes=(1, 2, 4, 8, 16), n_tasks=128, verbose=True) -> list[dict]:
    rows = weak_scaling(node_counts=weak_nodes)
    rows += cost_curve(n_tasks=n_tasks, static_counts=weak_nodes)
    write_csv("exp7_elastic", rows)
    if verbose:
        print_rows(rows)
    return rows


def main(full: bool = False, smoke: bool = False):
    if smoke:
        return run(weak_nodes=(1, 4), n_tasks=24)
    if full:
        return run(weak_nodes=(1, 2, 4, 8, 16), n_tasks=128)
    return run(weak_nodes=(1, 2, 4, 8), n_tasks=64)


if __name__ == "__main__":
    import sys

    main(full="--full" in sys.argv, smoke="--smoke" in sys.argv)
