"""Exp 5 (beyond-paper): provider groups — balanced throughput + failover.

Two questions, per EXPERIMENTS.md §Perf:

  1. What does the group indirection cost?  OVH/TH/TPT for the same noop
     workload bound to a 1-, 2-, and 4-member group (members are identical
     cloud pools, so k=1 isolates the indirection itself: bind to a group
     that degenerates to one provider vs. the member count scaling).
  2. What does failover cost?  The same sleep workload on a k-member group
     with one member killed mid-run vs. undisturbed; the delta in wall time
     is the failover overhead (orphan collection + re-partition + re-submit
     to surviving members).
"""
from __future__ import annotations

import time

from repro.core import Hydra, ProviderSpec, Task

from benchmarks.common import print_rows, write_csv


def _member_specs(k: int, concurrency: int = 4) -> list[ProviderSpec]:
    return [ProviderSpec(name=f"m{i}", concurrency=concurrency) for i in range(k)]


def _run(k: int, n_tasks: int, kill_member: bool, sleep_s: float = 0.0):
    h = Hydra(pod_store="memory", tasks_per_pod=16)
    group = h.register_group("pool", _member_specs(k), strategy="round_robin")
    kind = "sleep" if sleep_s else "noop"
    tasks = [Task(kind=kind, duration=sleep_s) for _ in range(n_tasks)]
    t0 = time.perf_counter()
    sub = h.submit(tasks)
    if kill_member:
        h.manager("m0").fail()  # ProviderDown mid-run -> in-group failover
    ok = sub.wait(timeout=600)
    wall = time.perf_counter() - t0
    m = sub.metrics()
    states = dict(sub.states)
    breaker = group.breaker_state("m0").value
    h.shutdown(wait=False)
    assert ok and states == {"DONE": n_tasks}, (k, kill_member, states)
    return wall, m, breaker


def main(full: bool = False) -> list[dict]:
    n_noop = 2000 if full else 400
    n_sleep = 600 if full else 150
    sleep_s = 0.004
    rows = []
    for k in (1, 2, 4):
        # balanced throughput: pure broker path, no failure
        wall, m, _ = _run(k, n_noop, kill_member=False)
        rows.append(
            {"exp": "throughput", "members": k, "failover": 0, "wall_s": round(wall, 4), **m.row()}
        )
        # failover overhead: kill one member mid-run (k=1 has no survivor to
        # fail over to, so the baseline row doubles as its failover bound)
        base_wall, base_m, _ = _run(k, n_sleep, kill_member=False, sleep_s=sleep_s)
        if k > 1:
            fail_wall, fail_m, breaker = _run(k, n_sleep, kill_member=True, sleep_s=sleep_s)
            rows.append(
                {
                    "exp": "failover",
                    "members": k,
                    "failover": 1,
                    "wall_s": round(fail_wall, 4),
                    "failover_overhead_s": round(fail_wall - base_wall, 4),
                    "breaker_m0": breaker,
                    **fail_m.row(),
                }
            )
        else:
            rows.append(
                {
                    "exp": "failover",
                    "members": k,
                    "failover": 0,
                    "wall_s": round(base_wall, 4),
                    "failover_overhead_s": 0.0,
                    "breaker_m0": "CLOSED",
                    **base_m.row(),
                }
            )
    write_csv("exp5_groups", rows)
    print_rows(rows)
    return rows


if __name__ == "__main__":
    main("--full" in __import__("sys").argv)
