"""Shared benchmark fixtures: the paper's provider set, CSV output, sizing.

Scale disclosure: the paper ran on 4-16 vCPU cloud VMs and a 128-core/node
HPC system; this container has ONE core.  Default sizes are scaled down so
``python -m benchmarks.run`` completes in minutes; ``--full`` uses the
paper's task counts.  We validate the paper's *claims* (invariances, ratios,
scaling shapes), not its absolute seconds - same protocol (noop tasks,
identical metric definitions).
"""
from __future__ import annotations

import csv
import os

from repro.core import Hydra, ProviderSpec

RESULT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


# The paper's platforms (Table 1): Jetstream2, Chameleon, AWS, Azure clouds +
# Bridges2 HPC.  Concurrency models vCPUs; env/submit latencies model the
# platform API behaviour (zeroed for OVH-isolation runs, per the paper's
# noop methodology).
def cloud_provider(name: str, vcpus: int = 4, submit_latency_s: float = 0.0) -> ProviderSpec:
    return ProviderSpec(
        name=name,
        platform="cloud",
        connector="caas",
        concurrency=vcpus,
        submit_latency_s=submit_latency_s,
    )


def hpc_provider(name: str = "bridges2", cores: int = 8, queue_delay_s: float = 0.0) -> ProviderSpec:
    return ProviderSpec(
        name=name,
        platform="hpc",
        connector="pilot",
        concurrency=cores,
        queue_delay_s=queue_delay_s,
    )


CLOUDS = ("jet2", "chi", "aws", "azure")


def make_broker(pod_store: str = "disk", policy: str = "round_robin", **kw) -> Hydra:
    """pod_store='disk' is the paper-faithful baseline; 'memory' is the
    paper's named future-work optimization (measured in §Perf)."""
    return Hydra(policy=policy, pod_store=pod_store, **kw)


def write_csv(name: str, rows: list[dict]) -> str:
    os.makedirs(RESULT_DIR, exist_ok=True)
    path = os.path.join(RESULT_DIR, f"{name}.csv")
    if rows:
        keys = sorted({k for r in rows for k in r}, key=lambda k: (k not in rows[0], k))
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys)
            w.writeheader()
            w.writerows(rows)
    return path


def print_rows(rows: list[dict]) -> None:
    for r in rows:
        print("  " + ",".join(f"{k}={v}" for k, v in r.items()))
