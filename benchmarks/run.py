"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV summary rows (plus per-experiment
CSV files under artifacts/bench/).  ``--full`` uses the paper's task counts.
"""
from __future__ import annotations

import sys
import time


def _summary(name: str, rows: list[dict], key: str = "th_tasks_per_s") -> str:
    if not rows:
        return f"{name},0,empty"
    vals = [r[key] for r in rows if key in r]
    n_tasks = sum(r.get("n_tasks", 0) for r in rows)
    ovh = [r["ovh_s"] for r in rows if "ovh_s" in r]
    us_per_task = (sum(ovh) / max(n_tasks, 1)) * 1e6 if ovh else 0.0
    derived = f"mean_{key}={sum(vals)/len(vals):.1f}" if vals else "n/a"
    return f"{name},{us_per_task:.2f},{derived}"


def main() -> None:
    full = "--full" in sys.argv
    out = []

    from benchmarks import exp1_per_provider, exp2_cross_provider, exp3a_cross_platform
    from benchmarks import exp3b_heterogeneous, exp4_facts, exp5_groups, exp6_streaming
    from benchmarks import kernels_bench, roofline_report

    print("== Exp 1: per-provider scaling (OVH/TH/TPT, MCPP vs SCPP) ==")
    r1 = exp1_per_provider.main(full)
    out.append(_summary("exp1_per_provider", r1))

    print("== Exp 2: cross-provider aggregation ==")
    r2 = exp2_cross_provider.main(full)
    out.append(_summary("exp2_cross_provider", r2))

    print("== Exp 3A: cloud + HPC homogeneous ==")
    r3a = exp3a_cross_platform.main(full)
    out.append(_summary("exp3a_cross_platform", r3a))

    print("== Exp 3B: heterogeneous tasks/nodes ==")
    r3b = exp3b_heterogeneous.main(full)
    out.append(_summary("exp3b_heterogeneous", r3b))

    print("== Exp 4: FACTS workflows ==")
    r4 = exp4_facts.main(full)
    ovh_fracs = [r["ovh_frac"] for r in r4]
    out.append(f"exp4_facts,{sum(r['ttx_s'] for r in r4)/len(r4)*1e6:.0f},mean_ovh_frac={sum(ovh_fracs)/len(ovh_fracs):.4f}")

    print("== Exp 5: provider groups (balanced TPT + failover OVH) ==")
    r5 = exp5_groups.main(full)
    out.append(_summary("exp5_groups", r5))

    print("== Exp 6: streaming vs frontier DAG dispatch ==")
    r6 = exp6_streaming.main(full)
    streaming_rows = [r for r in r6 if r["mode"] == "streaming"]
    mean_pod_ratio = sum(r["pod_ratio"] for r in streaming_rows) / max(len(streaming_rows), 1)
    out.append(
        f"exp6_streaming,{sum(r['n_submits'] for r in streaming_rows)},mean_pod_ratio={mean_pod_ratio:.2f}"
    )

    print("== Kernel micro-benchmarks ==")
    for name, us, derived in kernels_bench.main(full):
        out.append(f"{name},{us:.1f},{derived}")

    print("== Roofline table (from dry-run artifacts) ==")
    rl = roofline_report.main(full)
    if rl:
        mean_mfu = sum(r["mfu_est"] for r in rl) / len(rl)
        out.append(f"roofline_cells,{len(rl)},mean_mfu_est={mean_mfu:.4f}")

    print("\nname,us_per_call,derived")
    for line in out:
        print(line)


if __name__ == "__main__":
    main()
