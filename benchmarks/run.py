"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV summary rows (plus per-experiment
CSV files under artifacts/bench/).  ``--full`` uses the paper's task counts;
``--smoke`` runs a CI-sized subset (tiny task counts, virtual-clock elastic
run) and writes the summary to ``artifacts/bench/BENCH_smoke.json`` so every
PR captures its perf trajectory as a workflow artifact.
"""
from __future__ import annotations

import json
import os
import sys
import time


def _summary(name: str, rows: list[dict], key: str = "th_tasks_per_s") -> str:
    if not rows:
        return f"{name},0,empty"
    vals = [r[key] for r in rows if key in r]
    n_tasks = sum(r.get("n_tasks", 0) for r in rows)
    ovh = [r["ovh_s"] for r in rows if "ovh_s" in r]
    us_per_task = (sum(ovh) / max(n_tasks, 1)) * 1e6 if ovh else 0.0
    derived = f"mean_{key}={sum(vals)/len(vals):.1f}" if vals else "n/a"
    return f"{name},{us_per_task:.2f},{derived}"


def _exp6_summary(rows: list[dict]) -> str:
    streaming_rows = [r for r in rows if r["mode"] == "streaming"]
    mean_pod_ratio = sum(r["pod_ratio"] for r in streaming_rows) / max(len(streaming_rows), 1)
    return (
        f"exp6_streaming,{sum(r['n_submits'] for r in streaming_rows)},"
        f"mean_pod_ratio={mean_pod_ratio:.2f}"
    )


def _exp8_summary(rows: list[dict]) -> str:
    aware = next(r for r in rows if r["mode"] == "aware")
    return (
        f"exp8_staging,{aware['mb_moved']},"
        f"bytes_reduction={aware['bytes_reduction']:.3f}"
        f"_makespan_speedup={aware['makespan_speedup']:.3f}"
    )


def _exp9_summary(rows: list[dict]) -> str:
    scaling = [r for r in rows if r["mode"] == "scaling"]
    data = next(r for r in rows if r["mode"] == "data")
    flat = scaling[-1]["us_per_task"] / max(scaling[0]["us_per_task"], 1e-9)
    return (
        f"exp9_sched,{scaling[-1]['us_per_task']},"
        f"dispatch_tasks_per_s={data['dispatch_tasks_per_s']:.0f}"
        f"_cost_flat_ratio={flat:.2f}"
    )


def _exp10_summary(rows: list[dict]) -> str:
    r = rows[0]
    return (
        f"exp10_scenario,{r['n_tasks']},"
        f"makespan_inflation={r['makespan_inflation']:.4f}"
        f"_recovery_s={r['recovery_s']:.1f}"
        f"_failed={r['failed']}"
        f"_violations={r['violations']}"
    )


def _exp11_summary(rows: list[dict]) -> str:
    flooded = next(r for r in rows if r["mode"] == "flooded")
    return (
        f"exp11_tenants,{flooded['n_flood']},"
        f"interactive_p99_ratio={flooded['interactive_p99_ratio']:.3f}"
        f"_flooded_p99_s={flooded['p99_s']:.3f}"
        f"_rejections={flooded['rejections']}"
    )


def _exp12_summary(rows: list[dict]) -> str:
    emit = next(r for r in rows if r["mode"] == "emit")
    replay = next(r for r in rows if r["mode"] == "replay")
    disp = next(r for r in rows if r["mode"] == "dispatch")
    delta = disp.get("delta_vs_baseline")
    delta_s = f"{delta:+.3f}" if delta is not None else "n/a"
    return (
        f"exp12_events,{emit['us_per_event']},"
        f"emit_events_per_s={emit['events_per_s']:.0f}"
        f"_replay_events_per_s={replay['events_per_s']:.0f}"
        f"_dispatch_delta={delta_s}"
    )


def _exp13_summary(rows: list[dict]) -> str:
    spot = next(r for r in rows if r["mode"] == "spot_mix")
    storm = next(r for r in rows if r["mode"] == "storm")
    return (
        f"exp13_market,{spot['n_tasks']},"
        f"cost_ratio={spot['cost_ratio']:.4f}"
        f"_failed={storm['failed']}"
        f"_reexec_frac={storm['reexec_frac']:.4f}"
        f"_slo_violations={spot['slo_violations'] + storm['slo_violations']}"
    )


def _exp7_summary(rows: list[dict]) -> str:
    weak = [r for r in rows if r["mode"] == "weak"]
    elastic = [r for r in rows if r["mode"] == "elastic"]
    scaled = all(r["scaled_to_demand"] for r in weak) if weak else False
    cost = elastic[0]["cost_vs_max_static"] if elastic else 1.0
    return f"exp7_elastic,{len(weak)},scaled_to_demand={scaled}_cost_vs_static={cost:.3f}"


def _write_bench_json(tag: str, out: list[str]) -> str:
    """BENCH_<tag>.json: the per-PR perf-trajectory artifact CI uploads."""
    from benchmarks.common import RESULT_DIR

    os.makedirs(RESULT_DIR, exist_ok=True)
    path = os.path.join(RESULT_DIR, f"BENCH_{tag}.json")
    rows = []
    for line in out:
        name, us, derived = line.split(",", 2)
        rows.append({"name": name, "us_per_call": float(us), "derived": derived})
    with open(path, "w") as f:
        json.dump(
            {"tag": tag, "unix_time": time.time(), "rows": rows},
            f,
            indent=2,
        )
    return path


def run_smoke() -> list[str]:
    """CI-sized: broker-core experiments at tiny counts (elastic run on a
    virtual clock) plus the kernel lane — per-kernel XLA parity rows and
    the exp14 autotuner arm at smoke shapes."""
    out = []

    from benchmarks import (
        exp1_per_provider,
        exp4_facts,
        exp6_streaming,
        exp7_elastic,
        exp8_staging,
        exp9_sched,
        exp10_scenario,
        exp11_tenants,
        exp12_events,
        exp13_market,
        kernels_bench,
    )

    print("== Exp 1 (smoke): per-provider scaling ==")
    out.append(_summary("exp1_per_provider", exp1_per_provider.main(False)))

    print("== Exp 4 (smoke): FACTS workflows ==")
    r4 = exp4_facts.main(smoke=True)
    ovh_fracs = [r["ovh_frac"] for r in r4]
    out.append(
        f"exp4_facts,{sum(r['ttx_s'] for r in r4)/len(r4)*1e6:.0f},"
        f"mean_ovh_frac={sum(ovh_fracs)/len(ovh_fracs):.4f}"
    )

    print("== Exp 6 (smoke): streaming vs frontier ==")
    out.append(_exp6_summary(exp6_streaming.main(False)))

    print("== Exp 7 (smoke): elastic acquisition ==")
    out.append(_exp7_summary(exp7_elastic.main(smoke=True)))

    print("== Exp 8 (smoke): data-aware staging ==")
    out.append(_exp8_summary(exp8_staging.main(smoke=True)))

    print("== Exp 9 (smoke): scheduler-core dispatch throughput ==")
    out.append(_exp9_summary(exp9_sched.main(smoke=True)))

    print("== Exp 10 (smoke): chaos scenario (searise-smoke, chaos + twin) ==")
    out.append(_exp10_summary(exp10_scenario.main(smoke=True)))

    print("== Exp 11 (smoke): multi-tenant front door (10k flood) ==")
    out.append(_exp11_summary(exp11_tenants.main(smoke=True)))

    print("== Exp 12 (smoke): event-bus overhead (emit/replay/dispatch tax) ==")
    out.append(_exp12_summary(exp12_events.main(smoke=True)))

    print("== Exp 13 (smoke): market scheduler (spot mix + preemption storm) ==")
    out.append(_exp13_summary(exp13_market.main(smoke=True)))

    print("== Exp 14 (smoke): Pallas kernels (XLA parity + autotuner demo) ==")
    for name, us, derived in kernels_bench.main(False):
        out.append(f"{name},{us:.1f},{derived}")

    path = _write_bench_json("smoke", out)
    print(f"\nwrote {path}")
    return out


def run_all(full: bool) -> list[str]:
    out = []

    from benchmarks import exp1_per_provider, exp2_cross_provider, exp3a_cross_platform
    from benchmarks import exp3b_heterogeneous, exp4_facts, exp5_groups, exp6_streaming
    from benchmarks import exp7_elastic, exp8_staging, exp9_sched, exp10_scenario
    from benchmarks import exp11_tenants, exp12_events, exp13_market
    from benchmarks import kernels_bench, roofline_report

    print("== Exp 1: per-provider scaling (OVH/TH/TPT, MCPP vs SCPP) ==")
    r1 = exp1_per_provider.main(full)
    out.append(_summary("exp1_per_provider", r1))

    print("== Exp 2: cross-provider aggregation ==")
    r2 = exp2_cross_provider.main(full)
    out.append(_summary("exp2_cross_provider", r2))

    print("== Exp 3A: cloud + HPC homogeneous ==")
    r3a = exp3a_cross_platform.main(full)
    out.append(_summary("exp3a_cross_platform", r3a))

    print("== Exp 3B: heterogeneous tasks/nodes ==")
    r3b = exp3b_heterogeneous.main(full)
    out.append(_summary("exp3b_heterogeneous", r3b))

    print("== Exp 4: FACTS workflows ==")
    r4 = exp4_facts.main(full)
    ovh_fracs = [r["ovh_frac"] for r in r4]
    out.append(
        f"exp4_facts,{sum(r['ttx_s'] for r in r4)/len(r4)*1e6:.0f},"
        f"mean_ovh_frac={sum(ovh_fracs)/len(ovh_fracs):.4f}"
    )

    print("== Exp 5: provider groups (balanced TPT + failover OVH) ==")
    r5 = exp5_groups.main(full)
    out.append(_summary("exp5_groups", r5))

    print("== Exp 6: streaming vs frontier DAG dispatch ==")
    out.append(_exp6_summary(exp6_streaming.main(full)))

    print("== Exp 7: elastic acquisition (weak scaling + cost curve) ==")
    out.append(_exp7_summary(exp7_elastic.main(full)))

    print("== Exp 8: data-aware staging (locality-aware vs blind placement) ==")
    out.append(_exp8_summary(exp8_staging.main(full)))

    print("== Exp 9: scheduler-core dispatch throughput (ledger + heaps) ==")
    out.append(_exp9_summary(exp9_sched.main(full)))

    print("== Exp 10: chaos scenario (searise, chaos + no-chaos twin) ==")
    out.append(_exp10_summary(exp10_scenario.main(full)))

    print("== Exp 11: multi-tenant front door (interactive p99 under flood) ==")
    out.append(_exp11_summary(exp11_tenants.main(full)))

    print("== Exp 12: event-bus overhead (emit/replay/dispatch tax) ==")
    out.append(_exp12_summary(exp12_events.main(full)))

    print("== Exp 13: market scheduler (spot mix + preemption storm) ==")
    out.append(_exp13_summary(exp13_market.main(full)))

    print("== Kernel micro-benchmarks ==")
    for name, us, derived in kernels_bench.main(full):
        out.append(f"{name},{us:.1f},{derived}")

    print("== Roofline table (from dry-run artifacts) ==")
    rl = roofline_report.main(full)
    if rl:
        mean_mfu = sum(r["mfu_est"] for r in rl) / len(rl)
        out.append(f"roofline_cells,{len(rl)},mean_mfu_est={mean_mfu:.4f}")

    _write_bench_json("full" if full else "default", out)
    return out


def main() -> None:
    if "--smoke" in sys.argv:
        out = run_smoke()
    else:
        out = run_all("--full" in sys.argv)
    print("\nname,us_per_call,derived")
    for line in out:
        print(line)


if __name__ == "__main__":
    main()
