"""Experiment 13: the resource market — cost-aware platform mix + checkpoint
recovery under a preemption storm.

The paper brokers platforms that differ in price and revocation risk, not
just acquisition latency (§1, §4).  Three arms, identical workload:

  ondemand   - all on-demand capacity ($1.00/slot-hr, ~stable).  The cost
               and makespan baseline; its makespan (x a small margin)
               defines the SLO the cheaper mixes must still meet.
  spot_mix   - the MarketPlanner bids over cheap-but-hazardous spot
               ($0.25/slot-hr, ~6 revocations/instance-hr modeled) with a
               small on-demand fallback.  Claim: same makespan SLO at
               <= 0.8x the on-demand dollar cost (gated in check_bench.py).
  storm      - the spot mix with a TaskCheckpointer attached, under a
               seeded preemption storm that kills >= 20% of the live spot
               instances mid-run (site death under RUNNING tasks: the
               _collect_orphans resume path).  Claims: ZERO failed tasks,
               and <= 25% of preempted work re-executed (write-behind
               checkpoints lose only the tail past the last interval).

Everything runs under a VirtualClock with fixed acquisition latencies and
seeded draws: same seed => same bid schedule, same victim set.
"""
from __future__ import annotations

import math
import random
import time

from repro.core import Hydra, LaunchSpec, ProviderPool, Task
from repro.core.autoscaler import LatencyModel
from repro.core.market import MarketPlanner, PreemptionHazard
from repro.core.provider import ProviderSpec
from repro.runtime.clock import get_clock, virtual_time

from benchmarks.common import print_rows, write_csv

SPOT_PRICE = 0.25  # $/slot-hr
ONDEMAND_PRICE = 1.00
SPOT_RATE = 6.0  # modeled revocations per instance-hour
SLO_MARGIN = 1.25  # spot mix must land within this factor of on-demand


def _launches(mode: str, max_instances: int) -> list[LaunchSpec]:
    fixed = LatencyModel(distribution="fixed", mean_s=8.0)
    ondemand = LaunchSpec(
        template=ProviderSpec(name="ond", platform="cloud", concurrency=8),
        min_instances=1,
        max_instances=max_instances if mode == "ondemand" else 2,
        latency=fixed,
        price_per_slot_hour=ONDEMAND_PRICE,
    )
    if mode == "ondemand":
        return [ondemand]
    spot = LaunchSpec(
        template=ProviderSpec(name="spot", platform="cloud", concurrency=8),
        min_instances=0,
        max_instances=max_instances,
        latency=fixed,
        price_per_slot_hour=SPOT_PRICE,
        hazard=PreemptionHazard(rate_per_hour=SPOT_RATE),
    )
    return [spot, ondemand]


def _run_arm(
    mode: str,
    n_tasks: int,
    task_s: float = 12.0,
    max_instances: int = 6,
    storm_at_s: float = 0.0,
    storm_kill_frac: float = 0.34,
    seed: int = 1234,
    real_timeout_s: float = 120.0,
) -> dict:
    """One arm under its own VirtualClock; returns the row for the table."""
    with virtual_time():
        h = Hydra(streaming=True, pod_store="memory", batch_window=0.002)
        ckpt = None
        if mode == "storm":
            ckpt = h.enable_task_checkpoints(interval_s=1.0)
        pool = ProviderPool(_launches(mode, max_instances), seed=seed)
        planner = MarketPlanner(slo_target_s=60.0, seed=seed)
        scaler = h.autoscale(
            pool,
            tick_s=1.0,
            warmup_ticks=2,
            cooldown_ticks=4,
            scale_out_pressure=1.2,
            max_concurrent_acquisitions=max_instances,
            planner=planner,
        )
        tasks = [Task(kind="sleep", duration=task_s) for _ in range(n_tasks)]
        t0 = get_clock().now()
        h.dispatch(tasks)

        storm_done = mode != "storm"
        n_spot_live = n_killed = 0
        rng = random.Random(seed)
        deadline = time.monotonic() + real_timeout_s
        while time.monotonic() < deadline:
            if all(t.done() for t in tasks):
                break
            if not storm_done and get_clock().now() - t0 >= storm_at_s:
                # the seeded storm: revoke >= storm_kill_frac of the live
                # spot fleet at once (site death under RUNNING tasks)
                storm_done = True
                live = sorted(
                    n for n in scaler.pool.live_instances()
                    if n.startswith("spot")
                )
                n_spot_live = len(live)
                victims = rng.sample(
                    live, max(1, math.ceil(storm_kill_frac * len(live)))
                ) if live else []
                for name in victims:
                    h.remove_provider(name, drain=False, deregister=False)
                    scaler.note_provider_lost(name)
                n_killed = len(victims)
            time.sleep(0.02)
        assert all(t.done() for t in tasks), f"exp13/{mode}: tasks did not drain"
        failed = sum(1 for t in tasks if t.exception() is not None)
        ends = [t.trace.last("exec_done") for t in tasks]
        makespan = max(e for e in ends if e is not None) - t0
        h.shutdown(wait=True)  # settles still-live instances into the ledger
        report = planner.cost_report()
        row = {
            "mode": mode,
            "n_tasks": n_tasks,
            "makespan_s": round(makespan, 2),
            "node_seconds": round(report["node_seconds"], 1),
            "dollars": round(report["dollars"], 4),
            "bids": report["bids"],
            "bids_by_template": ";".join(
                f"{k}:{v}" for k, v in sorted(report["bids_by_template"].items())
            ),
            "failed": failed,
            "resumed": sum(1 for t in tasks if t.resumes > 0),
            "retries_charged": sum(t.retries for t in tasks),
        }
        if mode == "storm":
            stats = ckpt.stats()
            row["spot_live_at_storm"] = n_spot_live
            row["spot_killed"] = n_killed
            row["reexecuted_s"] = round(stats["reexecuted_s"], 2)
            row["preempted_work_s"] = round(stats["preempted_work_s"], 2)
            row["reexec_frac"] = round(stats["reexec_frac"], 4)
        return row


def run(
    n_tasks: int = 96,
    task_s: float = 12.0,
    max_instances: int = 6,
    seed: int = 1234,
    verbose: bool = True,
) -> list[dict]:
    ondemand = _run_arm("ondemand", n_tasks, task_s, max_instances, seed=seed)
    spot = _run_arm("spot_mix", n_tasks, task_s, max_instances, seed=seed)
    # storm lands mid-first-wave: capacity is up and most tasks are RUNNING
    # past their first checkpoint interval
    storm = _run_arm(
        "storm",
        n_tasks,
        task_s,
        max_instances,
        storm_at_s=16.0,
        seed=seed,
    )
    slo_s = SLO_MARGIN * ondemand["makespan_s"]
    for row in (ondemand, spot, storm):
        row["cost_ratio"] = round(
            row["dollars"] / max(ondemand["dollars"], 1e-9), 4
        )
        row["slo_s"] = round(slo_s, 2)
        row["slo_violations"] = int(row["makespan_s"] > slo_s)
    rows = [ondemand, spot, storm]
    write_csv("exp13_market", rows)
    if verbose:
        print_rows(rows)
    return rows


def main(full: bool = False, smoke: bool = False) -> list[dict]:
    if smoke:
        return run(n_tasks=48, max_instances=4)
    if full:
        return run(n_tasks=192, max_instances=8)
    return run()


if __name__ == "__main__":
    import sys

    main(full="--full" in sys.argv, smoke="--smoke" in sys.argv)
