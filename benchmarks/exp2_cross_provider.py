"""Experiment 2: cross-provider scalability (paper §5.2).

One workload split concurrently across all four cloud providers.  Claims:
  * aggregated OVH consistent with Exp 1 at the per-provider share,
  * aggregated TH ~ 4x the single-provider TH,
  * MCPP-vs-SCPP behaviour replicates Exp 1.
"""
from __future__ import annotations

from repro.core import Task

from benchmarks.common import CLOUDS, cloud_provider, make_broker, print_rows, write_csv


def run(n_tasks_list=(2000, 4000, 8000), vcpus=16, pod_store="disk", verbose=True) -> list[dict]:
    rows = []
    for n_tasks in n_tasks_list:
        for model in ("mcpp", "scpp"):
            h = make_broker(pod_store=pod_store, policy="round_robin")
            for c in CLOUDS:
                h.register_provider(cloud_provider(c, vcpus=vcpus))
            tasks = [Task(kind="noop") for _ in range(n_tasks)]
            sub = h.submit(tasks, partitioning=model)
            sub.wait(timeout=600)
            m = sub.metrics()
            rows.append({
                "exp": "exp2", "providers": len(CLOUDS), "n_tasks": n_tasks,
                "model": model, "pod_store": pod_store, **m.row(),
            })
            h.shutdown(wait=False)
    write_csv(f"exp2_cross_provider_{pod_store}", rows)
    if verbose:
        print_rows(rows)
    return rows


def main(full: bool = False):
    sizes = (16000, 32000, 64000) if full else (2000, 4000, 8000)
    return run(n_tasks_list=sizes)


if __name__ == "__main__":
    import sys

    main(full="--full" in sys.argv)
