"""Experiment 3A: cross-platform (cloud + HPC) scalability (paper §5.3).

Homogeneous noop workload over 4 clouds + 1 HPC pilot, SCPP (the paper uses
SCPP as tasks execute outside pods on HPC).  Claim: the HPC connector adds
no overhead class beyond the cloud connectors (OVH/TH match Exp 2).
"""
from __future__ import annotations

from repro.core import Task

from benchmarks.common import CLOUDS, cloud_provider, hpc_provider, make_broker, print_rows, write_csv


def run(n_tasks_list=(2500, 5000, 10000), vcpus=16, pod_store="disk", verbose=True) -> list[dict]:
    rows = []
    for n_tasks in n_tasks_list:
        h = make_broker(pod_store=pod_store)
        for c in CLOUDS:
            h.register_provider(cloud_provider(c, vcpus=vcpus))
        h.register_provider(hpc_provider(cores=vcpus))
        tasks = [Task(kind="noop") for _ in range(n_tasks)]
        sub = h.submit(tasks, partitioning="scpp")
        sub.wait(timeout=600)
        m = sub.metrics()
        rows.append({
            "exp": "exp3a", "providers": len(CLOUDS) + 1, "n_tasks": n_tasks,
            "model": "scpp", "pod_store": pod_store, **m.row(),
        })
        h.shutdown(wait=False)
    write_csv(f"exp3a_cross_platform_{pod_store}", rows)
    if verbose:
        print_rows(rows)
    return rows


def main(full: bool = False):
    sizes = (20000, 40000, 80000) if full else (2500, 5000, 10000)
    return run(n_tasks_list=sizes)


if __name__ == "__main__":
    import sys

    main(full="--full" in sys.argv)
