"""Experiment 12: event-bus overhead (core/events.py).

The event-sourced control plane puts one ``EventBus.emit`` adjacent to
every legacy counter increment on the broker's hot paths.  ``emit`` is a
clock stamp + list append + one dict-reduce under a single lock, and the
dispatcher pays it per BATCH (not per task), so the designed cost is noise
against the ~87 us/task dispatch floor (exp9).  This experiment measures
that claim directly rather than asserting it:

  emit     - raw bus throughput: events/s for a hot single-threaded emit
             loop (the per-event cost every instrumented site pays), with
             and without a bounded HYDRA_EVENTS_BUFFER.
  replay   - fold throughput: events/s re-deriving the metric views from a
             serialized JSONL stream (the offline replay path).
  dispatch - end-to-end tax: the exp9 smoke data arm (2k data-gravity
             tasks, 32 providers) re-run as-is — every dispatch now emits —
             reported as dispatch_tasks_per_s and the delta vs the
             committed pre-events baseline in artifacts/bench/
             BENCH_smoke.json (gated separately by check_bench.py).

Strict mode (HYDRA_EVENTS_CHECK) is intentionally OFF here, as in CI
benches: the cross-check is a test harness, not a production cost.
"""
from __future__ import annotations

import io
import json
import os
import time

from repro.core.events import EventBus, replay_jsonl

from benchmarks.common import RESULT_DIR, print_rows, write_csv

BASELINE_JSON = os.path.join(RESULT_DIR, "BENCH_smoke.json")


def _bench_emit(n_events: int, buffer: int = 0) -> dict:
    bus = EventBus(strict=False, buffer=buffer)
    t0 = time.perf_counter()
    for i in range(n_events):
        bus.emit("dispatch.batch", n=8)
    dt = time.perf_counter() - t0
    return {
        "exp": "exp12",
        "mode": f"emit_buf{buffer}" if buffer else "emit",
        "n_events": n_events,
        "wall_s": round(dt, 4),
        "events_per_s": round(n_events / dt, 1),
        "us_per_event": round(dt / n_events * 1e6, 3),
    }


def _bench_replay(n_events: int) -> dict:
    bus = EventBus(strict=False)
    for i in range(n_events):
        bus.emit("task.complete", provider=f"p{i % 32}", failed=False)
    buf = io.StringIO()
    bus.dump_jsonl(buf)
    lines = buf.getvalue().splitlines()
    t0 = time.perf_counter()
    view, header = replay_jsonl(lines)
    dt = time.perf_counter() - t0
    assert view.snapshot() == header["snapshot"], "replay diverged mid-bench"
    return {
        "exp": "exp12",
        "mode": "replay",
        "n_events": n_events,
        "wall_s": round(dt, 4),
        "events_per_s": round(n_events / dt, 1),
        "us_per_event": round(dt / n_events * 1e6, 3),
    }


def _baseline_dispatch_tasks_per_s() -> float | None:
    """The committed smoke gate value (pre- or post-events, whatever HEAD
    carries) — the delta this experiment reports is vs that number."""
    try:
        with open(BASELINE_JSON) as f:
            doc = json.load(f)
    except OSError:
        return None
    for row in doc.get("rows", []):
        if row.get("name") == "exp9_sched":
            import re

            m = re.search(r"dispatch_tasks_per_s=([0-9.]+)", row.get("derived", ""))
            if m:
                return float(m.group(1))
    return None


def _bench_dispatch(reps: int) -> dict:
    # the exact exp9 smoke data arm: 2k data-gravity tasks, 32 providers
    from benchmarks.exp9_sched import _best_of

    n_tasks, n_providers = 2_000, 32
    dt = _best_of(reps, n_tasks, n_providers, "data_gravity", 2048, 8, True)
    row = {
        "exp": "exp12",
        "mode": "dispatch",
        "n_events": n_tasks,
        "wall_s": round(dt, 3),
        "dispatch_tasks_per_s": round(n_tasks / dt, 1),
        "us_per_task": round(dt / n_tasks * 1e6, 1),
    }
    base = _baseline_dispatch_tasks_per_s()
    if base:
        row["baseline_tasks_per_s"] = base
        row["delta_vs_baseline"] = round(row["dispatch_tasks_per_s"] / base - 1.0, 4)
    return row


def run(emit_events: int = 200_000, replay_events: int = 100_000, reps: int = 2) -> list[dict]:
    rows = [
        _bench_emit(emit_events),
        _bench_emit(emit_events, buffer=4096),
        _bench_replay(replay_events),
        _bench_dispatch(reps),
    ]
    write_csv("exp12_events", rows)
    print_rows(rows)
    return rows


def main(full: bool = False, smoke: bool = False) -> list[dict]:
    if smoke:
        return run(emit_events=50_000, replay_events=25_000, reps=2)
    if full:
        return run(emit_events=1_000_000, replay_events=500_000, reps=3)
    return run()


if __name__ == "__main__":
    import sys

    main(full="--full" in sys.argv, smoke="--smoke" in sys.argv)
