"""Experiment 10: standing chaos scenarios — resilience as a measured,
gated quantity.

Runs a canonical sea-rise scenario (repro/scenarios) twice — once with the
chaos schedule armed, once as the no-chaos twin — on a VirtualClock, and
reports the resilience envelope:

  makespan_inflation   chaos makespan / twin makespan (the price of the
                       fault sequence after recovery; gated by check_bench)
  recovery_s           last recovered task's finish minus the first fault
  failed               failed tasks under chaos (MUST be 0; hard-gated)
  recovered/preempted  tasks that rode a fault-recovery path / were killed

``--smoke`` (the CI lane) uses ``searise_smoke``; the default and ``--full``
use ``searise_at_scale`` / ``searise_full``.  ``--report`` additionally
writes each run's full structured ScenarioReport JSON under
``artifacts/scenario/`` — the nightly workflow uploads that directory as
the scenario-report artifact.
"""
from __future__ import annotations

import json
import os
import time

from repro.scenarios import presets
from repro.scenarios.runner import check_invariants, makespan_inflation, run_scenario

from benchmarks.common import print_rows, write_csv

SCENARIO_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "scenario")


def _write_report(report) -> str:
    os.makedirs(SCENARIO_DIR, exist_ok=True)
    arm = "chaos" if report.chaos_enabled else "baseline"
    path = os.path.join(SCENARIO_DIR, f"REPORT_{report.name}_{arm}.json")
    with open(path, "w") as f:
        json.dump(report.to_dict(), f, indent=2, sort_keys=True)
    return path


def run(spec, report_files: bool = False, verbose: bool = True) -> list[dict]:
    t0 = time.time()
    chaos = run_scenario(spec, chaos=True)
    base = run_scenario(spec, chaos=False)
    wall_s = time.time() - t0
    violations = check_invariants(chaos, base, spec)
    row = {
        "scenario": spec.name,
        "seed": spec.seed,
        "n_tasks": chaos.n_tasks,
        "n_workflows": chaos.n_workflows,
        "failed": chaos.failed_tasks,
        "unresolved": chaos.unresolved_tasks,
        "makespan_chaos_s": round(chaos.makespan_s, 3),
        "makespan_base_s": round(base.makespan_s, 3),
        "makespan_inflation": round(makespan_inflation(chaos, base), 4),
        "recovery_s": round(chaos.recovery_s or 0.0, 3),
        "recovered_tasks": chaos.recovered_tasks,
        "preempted_tasks": chaos.preempted_tasks,
        "events_injected": sum(
            chaos.chaos_stats.get("injected", {}).values()
        ),
        "mirrored_mb": chaos.staging.get("mirrored_mb", 0.0),
        "violations": len(violations),
        "fingerprint": chaos.fingerprint(),
        "wall_s": round(wall_s, 2),
    }
    if report_files:
        for rep in (chaos, base):
            _write_report(rep)
    rows = [row]
    write_csv("exp10_scenario", rows)
    if verbose:
        print_rows(rows)
        for v in violations:
            print(f"  VIOLATION: {v}")
    return rows


def main(full: bool = False, smoke: bool = False, report: bool = False):
    if smoke:
        return run(presets.searise_smoke(), report_files=report)
    if full:
        return run(presets.searise_full(), report_files=report)
    return run(presets.searise_at_scale(), report_files=report)


if __name__ == "__main__":
    import sys

    main(
        full="--full" in sys.argv,
        smoke="--smoke" in sys.argv,
        report="--report" in sys.argv,
    )
