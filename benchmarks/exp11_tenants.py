"""Experiment 11: multi-tenant front door — interactive SLO under flood.

The serving story (ROADMAP: "millions of users needs a tenant layer above
the ready heap"): one tenant floods the broker with a huge batch backlog
while another sends a steady trickle of short interactive requests.  Without
the front door the flood buries the single ready heap and interactive
latency scales with the flood size; with admission control (bounded tenant
queue + typed AdmissionError backpressure) and SLO-class lanes (interactive
drains before queued batch backfill every round) the interactive p99 stays
within a small constant of its unloaded value, whatever the flood size.

Two arms, identical interactive trickle, virtual clock throughout:

  unloaded - the trickle alone: the p99 floor (task time + dispatch cost).
  flooded  - the same trickle racing a batch flood submitted through a
             bounded tenant queue; the flood submitter obeys backpressure
             (catches AdmissionError, sleeps, retries) — rejections > 0
             proves the front door actually throttled it.

Derived metrics:

  interactive_p99_ratio  flooded p99 / unloaded p99 — gated in
                         check_bench.py (<= 30% drift vs baseline, hard
                         absolute ceiling 3.0 on the fresh run).
  rejections             AdmissionError count the flood submitter absorbed.
"""
from __future__ import annotations

import threading
import time

from repro.core import Hydra, ProviderSpec, Task
from repro.core.admission import AdmissionError, TenantSpec
from repro.runtime.clock import get_clock, virtual_time

from benchmarks.common import print_rows, write_csv

INTERACTIVE_S = 0.25  # modeled interactive request runtime
FLOOD_TASK_S = 0.1  # modeled batch task runtime
TRICKLE_GAP_S = 0.5  # virtual seconds between interactive requests
FLOOD_CHUNK = 512  # tasks per dispatch() attempt
BULK_MAX_QUEUED = 2048  # the bounded tenant queue the flood slams into


def _percentile(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, max(0, int(q * len(sorted_vals)) - 1))
    return sorted_vals[idx]


def _run_arm(
    flood_tasks: int,
    n_interactive: int,
    concurrency: int,
    timeout_s: float = 900.0,
) -> dict:
    """One arm: an interactive trickle, optionally racing a bounded flood."""
    with virtual_time():
        h = Hydra(
            pod_store="memory",
            streaming=True,
            batch_window=0.0,
            max_batch=64,
            tenants=[
                TenantSpec(name="serve", weight=2.0),
                TenantSpec(name="bulk", weight=1.0, max_queued=BULK_MAX_QUEUED),
            ],
        )
        h.register_provider(ProviderSpec(name="p", concurrency=concurrency))
        clock = get_clock()
        rejections = 0
        flood: list[Task] = []
        t_start = time.perf_counter()

        def pump_flood() -> None:
            # the well-behaved bulk submitter: push chunks, absorb typed
            # backpressure, retry after the hinted (or a default) delay
            nonlocal rejections
            remaining = flood_tasks
            while remaining > 0:
                chunk = [
                    Task(kind="sleep", duration=FLOOD_TASK_S, tenant="bulk")
                    for _ in range(min(FLOOD_CHUNK, remaining))
                ]
                try:
                    h.dispatch(chunk)
                except AdmissionError as e:
                    rejections += 1
                    clock.sleep(e.retry_after_s or 1.0)
                    continue
                flood.extend(chunk)
                remaining -= len(chunk)

        pump = None
        if flood_tasks:
            pump = threading.Thread(target=pump_flood, daemon=True, name="flood")
            pump.start()
            clock.sleep(2.0)  # let the flood bury the queue before trickling

        latencies: list[float] = []
        serve: list[Task] = []
        for _ in range(n_interactive):
            t = Task(
                kind="sleep",
                duration=INTERACTIVE_S,
                tenant="serve",
                slo_class="interactive",
            )
            t0 = clock.now()
            h.dispatch([t])
            serve.append(t)
            t.add_done_callback(
                lambda _f, t=t, t0=t0: latencies.append(
                    (t.trace.last("exec_done") or t0) - t0
                )
            )
            clock.sleep(TRICKLE_GAP_S)

        deadline = time.monotonic() + timeout_s
        if pump is not None:
            pump.join(timeout=timeout_s)
        for t in serve + flood:
            left = deadline - time.monotonic()
            if left <= 0:
                raise TimeoutError(f"exp11: drain exceeded {timeout_s:.0f}s")
            t.result(timeout=left)
        wall_s = time.perf_counter() - t_start
        stats = h.tenant_stats()
        h.shutdown(wait=False)

    latencies.sort()
    return {
        "n_flood": flood_tasks,
        "n_interactive": n_interactive,
        "p50_s": round(_percentile(latencies, 0.50), 4),
        "p99_s": round(_percentile(latencies, 0.99), 4),
        "rejections": rejections,
        "admitted": stats.get("admitted", 0),
        "wall_s": round(wall_s, 3),
    }


def run(
    flood_tasks: int = 100_000,
    n_interactive: int = 200,
    concurrency: int = 16,
    verbose: bool = True,
) -> list[dict]:
    rows: list[dict] = []
    unloaded = _run_arm(0, n_interactive, concurrency)
    unloaded.update({"exp": "exp11", "mode": "unloaded"})
    rows.append(unloaded)
    flooded = _run_arm(flood_tasks, n_interactive, concurrency)
    flooded.update({"exp": "exp11", "mode": "flooded"})
    rows.append(flooded)
    ratio = flooded["p99_s"] / max(unloaded["p99_s"], 1e-9)
    for r in rows:
        r["interactive_p99_ratio"] = round(ratio, 3)
    write_csv("exp11_tenants", rows)
    if verbose:
        print_rows(rows)
    return rows


def main(full: bool = False, smoke: bool = False) -> list[dict]:
    if smoke:
        # CI-sized: a 10k flood is already 5x the bounded tenant queue, so
        # the backpressure loop and lane preemption are both exercised
        return run(flood_tasks=10_000, n_interactive=100)
    if full:
        return run()  # the nightly 100k flood
    return run(flood_tasks=20_000, n_interactive=100)


if __name__ == "__main__":
    import sys

    main(full="--full" in sys.argv, smoke="--smoke" in sys.argv)
