"""Kernel micro-bench: interpret-mode correctness timing + XLA-path timing.

On this CPU container the Pallas kernels run in interpret mode (orders of
magnitude slower than compiled Mosaic); the number that matters for the
repo's CI is the XLA-path (ref) timing and the allclose check.  Prints the
``name,us_per_call,derived`` rows required by benchmarks/run.py.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, reps=3) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def main(full: bool = False):
    rng = np.random.default_rng(0)
    rows = []

    B, H, KV, L, hd = 1, 4, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(B, H, L, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, KV, L, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, KV, L, hd)), jnp.float32)
    t_ref = _time(lambda q, k, v: ref.attention_ref(q, k, v, causal=True), q, k, v)
    err = float(jnp.max(jnp.abs(
        ops.flash_attention(q, k, v, causal=True) - ref.attention_ref(q, k, v, causal=True)
    )))
    rows.append(("flash_attention_ref_xla", t_ref, f"allclose_err={err:.2e}"))

    B, ck, di, N = 2, 64, 256, 16
    x = jnp.asarray(rng.normal(size=(B, ck, di)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, ck, di)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(B, ck, N)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(B, ck, N)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, (di, N)), jnp.float32)
    h0 = jnp.zeros((B, di, N), jnp.float32)
    t_ref = _time(lambda *a_: ref.selective_scan_chunk_ref(*a_), x, dt, bm, cm, a, h0)
    y1, h1 = ops.selective_scan_chunk(x, dt, bm, cm, a, h0, block_d=128)
    y2, h2 = ref.selective_scan_chunk_ref(x, dt, bm, cm, a, h0)
    err = float(jnp.max(jnp.abs(y1 - y2)))
    rows.append(("selective_scan_ref_xla", t_ref, f"allclose_err={err:.2e}"))

    B, L2, dr = 2, 128, 512
    la = -jnp.asarray(rng.uniform(0.01, 1.0, (B, L2, dr)), jnp.float32)
    gx = jnp.asarray(rng.normal(size=(B, L2, dr)), jnp.float32)
    h0r = jnp.zeros((B, dr), jnp.float32)
    t_ref = _time(lambda *a_: ref.rglru_ref(*a_), la, gx, h0r)
    y1, _ = ops.rglru_scan(la, gx, h0r, block_d=256)
    y2, _ = ref.rglru_ref(la, gx, h0r)
    err = float(jnp.max(jnp.abs(y1 - y2)))
    rows.append(("rglru_scan_ref_xla", t_ref, f"allclose_err={err:.2e}"))

    E, C, D, F = 4, 128, 256, 512
    x = jnp.asarray(rng.normal(size=(E, C, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(E, D, F)), jnp.float32)
    t_ref = _time(lambda *a_: ref.moe_gmm_ref(*a_), x, w)
    err = float(jnp.max(jnp.abs(
        ops.moe_gmm(x, w, block_c=64, block_f=128, block_d=128) - ref.moe_gmm_ref(x, w)
    )))
    rows.append(("moe_gmm_ref_xla", t_ref, f"allclose_err={err:.2e}"))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    main()
