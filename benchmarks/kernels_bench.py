"""Kernel micro-bench + exp14 autotuner arm (registry-driven, CI-gated).

Two row families feed ``BENCH_smoke.json`` (benchmarks/run.py --smoke) and
the check_bench.py gates:

  kernel_<name>   one row per registered kernel at its smoke (or --full)
                  shape: the us_per_call column is the XLA reference path
                  (the number that moves with real perf on this CPU host),
                  ``derived`` carries ``allclose_err`` (interpret-mode
                  Pallas vs reference, HARD-gated at 1e-3) and ``xla_us``
                  (relative 30% regression gate).
  exp14_kernels   the tuned-vs-default demonstration: the roofline
                  autotuner sweeps each demo shape (wall timer, warm-up +
                  min-of-3), the committed default config is timed the same
                  way, and the row reports the best tuned/default speedup
                  plus the pruner's sweep cut — both HARD-gated
                  (speedup >= 1.15x, cut >= 2x).

Demo shapes are deliberately small-batch/large-feature: on the interpret
path (and on the roofline model) those shapes make the grid-cell count the
dominant config-sensitive term, so the committed default block (512) is
measurably beaten by the full-width block the tuner picks.  See
docs/EXPERIMENTS.md §exp14 for measured numbers + noise discussion.
"""
from __future__ import annotations

import sys
import time

import jax

from repro.kernels import registry as kreg
from repro.kernels.autotune import Autotuner


def _time_us(fn, reps: int = 3) -> float:
    """Warm-up call (compile) + mean-of-reps, microseconds."""
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e6


def _min_s(fn, reps: int = 3) -> float:
    """Warm-up + min-of-reps, seconds (the autotuner's timing discipline)."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


# exp14 demo problems: small batch x full-width feature dim, where the
# default block (512) launches 2x the grid cells of the admissible maximum
# and the interpret path measures that directly (1.5-1.9x on this host)
DEMO_SHAPES = [
    ("rglru_scan", {"B": 1, "L": 64, "dr": 1024}),
    ("selective_scan", {"B": 1, "chunk": 32, "di": 1024, "N": 8}),
]


def kernel_rows(full: bool = False) -> list[tuple]:
    """One ``kernel_<name>`` row per registered kernel."""
    rows = []
    interpret = kreg.interpret_default()
    for name, kdef in kreg.KERNELS.items():
        shape = dict(kdef.full_shape if full else kdef.smoke_shape)
        args = kdef.make_args(shape, "float32", 0)
        t_ref = _time_us(lambda: kdef.ref(shape, args))
        err = kreg.max_abs_err(
            kdef.call(shape, args, kdef.defaults(shape), interpret),
            kdef.ref(shape, args),
        )
        rows.append(
            (f"kernel_{name}", t_ref, f"allclose_err={err:.2e}_xla_us={t_ref:.1f}")
        )
    return rows


def exp14_row(reps: int = 3) -> tuple:
    """Tuned-vs-default on the demo shapes; best speedup + worst sweep cut."""
    tuner = Autotuner(timer="wall", reps=reps)
    interpret = kreg.interpret_default()
    best = None  # (speedup, kernel, tuned_s, default_s, result)
    min_cut = float("inf")
    for name, shape in DEMO_SHAPES:
        kdef = kreg.get_kernel(name)
        result = tuner.tune(name, shape, "float32")
        min_cut = min(min_cut, result.sweep_cut)
        args = kdef.make_args(shape, "float32", 0)
        default_s = _min_s(
            lambda: kdef.call(shape, args, kdef.defaults(shape), interpret), reps
        )
        tuned_s = _min_s(
            lambda: kdef.call(shape, args, result.config, interpret), reps
        )
        speedup = default_s / tuned_s if tuned_s > 0 else float("inf")
        print(
            f"  exp14 {name}: tuned {kreg.config_sig(result.config)} "
            f"{tuned_s:.4f}s vs default {kreg.config_sig(kdef.defaults(shape))} "
            f"{default_s:.4f}s -> {speedup:.2f}x (cut {result.sweep_cut:.1f})"
        )
        if best is None or speedup > best[0]:
            best = (speedup, name, tuned_s, default_s)
    speedup, name, tuned_s, default_s = best
    derived = (
        f"tuned_speedup={speedup:.3f}_sweep_cut={min_cut:.1f}"
        f"_best_kernel={name}_tuned_s={tuned_s:.4f}_default_s={default_s:.4f}"
    )
    return ("exp14_kernels", tuned_s * 1e6, derived)


def main(full: bool = False) -> list[tuple]:
    rows = kernel_rows(full)
    rows.append(exp14_row())
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    main("--full" in sys.argv)
