"""CI regression gate for the smoke dispatch-throughput metric.

Compares a freshly produced ``BENCH_smoke.json`` against the committed
baseline and FAILS (exit 1) when the exp9 smoke dispatch throughput
regressed more than the tolerance (default 30%), so a PR that quietly
re-introduces an O(tasks x providers) term into the scheduler core cannot
merge green.  Improvements and small noise pass; the baseline is refreshed
by committing a new BENCH_smoke.json.

Usage (what .github/workflows/ci.yml runs):

    cp artifacts/bench/BENCH_smoke.json /tmp/bench_baseline.json
    PYTHONPATH=src python -m benchmarks.run --smoke
    PYTHONPATH=src python -m benchmarks.check_bench \
        /tmp/bench_baseline.json artifacts/bench/BENCH_smoke.json
"""
from __future__ import annotations

import json
import os
import re
import sys

ROW = "exp9_sched"
METRIC = "dispatch_tasks_per_s"
# overridable per environment (BENCH_GATE_TOLERANCE=0.5): the baseline is a
# committed absolute number, so a much slower CI runner class may need a
# wider gate until the baseline is re-committed from that class of machine
DEFAULT_TOLERANCE = float(os.environ.get("BENCH_GATE_TOLERANCE", "0.30"))


def metric_from(path: str) -> float:
    with open(path) as f:
        doc = json.load(f)
    for row in doc.get("rows", []):
        if row.get("name") == ROW:
            m = re.search(rf"{METRIC}=([0-9.]+)", row.get("derived", ""))
            if m:
                return float(m.group(1))
    raise SystemExit(f"{path}: no {ROW} row with a {METRIC} value")


def main(argv: list[str]) -> int:
    if len(argv) < 2:
        print(__doc__)
        return 2
    baseline_path, fresh_path = argv[0], argv[1]
    tolerance = float(argv[2]) if len(argv) > 2 else DEFAULT_TOLERANCE
    baseline = metric_from(baseline_path)
    fresh = metric_from(fresh_path)
    floor = baseline * (1.0 - tolerance)
    verdict = "OK" if fresh >= floor else "REGRESSION"
    print(
        f"{ROW}.{METRIC}: baseline={baseline:.0f} fresh={fresh:.0f} "
        f"floor={floor:.0f} (tolerance {tolerance:.0%}) -> {verdict}"
    )
    return 0 if fresh >= floor else 1


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
