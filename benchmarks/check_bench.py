"""CI regression gates over the smoke benchmark summary.

Compares a freshly produced ``BENCH_smoke.json`` against the committed
baseline and FAILS (exit 1) when a gated metric regressed past its
tolerance, so a PR that quietly re-introduces an O(tasks x providers) term
into the scheduler core — or a recovery path that inflates chaos makespans —
cannot merge green.  Improvements and small noise pass; the baseline is
refreshed by committing a new BENCH_smoke.json.

Gates:

  exp9_sched.dispatch_tasks_per_s    higher is better (throughput floor)
  exp10_scenario.makespan_inflation  lower is better (resilience ceiling)
  exp11_tenants.interactive_p99_ratio lower is better (widened 50% band —
                                     the p99 is quantized, see GATES), plus
                                     a HARD absolute ceiling of 3.0 on the
                                     fresh run
  exp10_scenario.failed              HARD: must be exactly 0 in the fresh run
  exp13_market.cost_ratio            HARD absolute ceiling 0.8: the spot mix
                                     must beat all-on-demand dollars by >= 20%
                                     while meeting the same makespan SLO
  exp13_market.failed                HARD: zero failed tasks under the
                                     preemption storm (checkpoint resumes)
  exp13_market.reexec_frac           HARD ceiling 0.25: <= 25% of preempted
                                     work re-executed after the storm
  kernel_<name>.xla_us               lower is better (per-kernel XLA-path
                                     latency, relative 30% gate)
  kernel_<name>.allclose_err         HARD ceiling 1e-3: a Pallas kernel that
                                     diverges from its reference fails CI
                                     like a ledger divergence
  exp14_kernels.tuned_speedup        HARD floor 1.15: the autotuned config
                                     must beat the committed default on at
                                     least one demo kernel/shape
  exp14_kernels.sweep_cut            HARD floor 2.0: the roofline pruner
                                     must cut the swept configs >= 2x vs
                                     the exhaustive space

A gated row missing from the *baseline* is skipped (first PR that adds the
experiment); missing from the *fresh* run it is an error (the experiment
silently disappeared).

Usage (what .github/workflows/ci.yml runs):

    cp artifacts/bench/BENCH_smoke.json /tmp/bench_baseline.json
    PYTHONPATH=src python -m benchmarks.run --smoke
    PYTHONPATH=src python -m benchmarks.check_bench \
        /tmp/bench_baseline.json artifacts/bench/BENCH_smoke.json
"""
from __future__ import annotations

import json
import os
import re
import sys
from dataclasses import dataclass
from typing import Optional

# overridable per environment (BENCH_GATE_TOLERANCE=0.5): baselines are
# committed absolute numbers, so a much slower CI runner class may need a
# wider gate until the baseline is re-committed from that class of machine
DEFAULT_TOLERANCE = float(os.environ.get("BENCH_GATE_TOLERANCE", "0.30"))


@dataclass(frozen=True)
class Gate:
    row: str
    metric: str
    higher_is_better: bool
    # overrides DEFAULT_TOLERANCE / the CLI tolerance for this gate only:
    # needed when the metric's own quantization is coarser than the global
    # 30% band, so one quantum of drift is not a regression
    tolerance: Optional[float] = None


KERNEL_NAMES = ("flash_attention", "selective_scan", "rglru_scan", "moe_gmm")

GATES = [
    Gate(row="exp9_sched", metric="dispatch_tasks_per_s", higher_is_better=True),
    Gate(row="exp10_scenario", metric="makespan_inflation", higher_is_better=False),
    # p99 over 100 interactive requests on the virtual clock is quantized to
    # ~0.05 s steps (observed modes: 0.35 and 0.5 flooded -> ratios 1.4 and
    # 2.0), so one scheduling quantum is a +-40% step and the default 30%
    # band flips on noise; 50% accepts the adjacent quantum while the HARD
    # absolute ceiling of 3.0 below still enforces the tenant-isolation SLO
    Gate(row="exp11_tenants", metric="interactive_p99_ratio", higher_is_better=False,
         tolerance=0.50),
] + [
    Gate(row=f"kernel_{k}", metric="xla_us", higher_is_better=False)
    for k in KERNEL_NAMES
]
# hard invariants on the fresh run, independent of any baseline
HARD_ZERO = [
    ("exp10_scenario", "failed"),
    ("exp10_scenario", "violations"),
    # the preemption storm must kill instances, never tasks; the spot mix
    # must also meet the on-demand makespan SLO (slo_violations covers both
    # market arms)
    ("exp13_market", "failed"),
    ("exp13_market", "slo_violations"),
]
# absolute ceilings on the fresh run: the relative gate above catches drift,
# this catches a baseline that was already bad (a 2.9 -> 3.5 ratio would pass
# a 30% drift check; an interactive p99 more than 3x its unloaded floor means
# the SLO lanes are not actually isolating tenants)
HARD_MAX = [
    ("exp11_tenants", "interactive_p99_ratio", 3.0),
    # the market's headline claims (ISSUE exp13): cheaper than on-demand by
    # >= 20%, and write-behind checkpoints bound storm re-execution
    ("exp13_market", "cost_ratio", 0.8),
    ("exp13_market", "reexec_frac", 0.25),
    # kernel correctness is a HARD gate: interpret-mode Pallas output must
    # match the XLA reference to 1e-3 on every registered kernel
] + [(f"kernel_{k}", "allclose_err", 1e-3) for k in KERNEL_NAMES]
# absolute floors on the fresh run (ISSUE exp14): the autotuner must beat
# the committed defaults somewhere real, and the roofline pruner must
# actually prune — a sweep that times the whole space "wins" trivially
HARD_MIN = [
    ("exp14_kernels", "tuned_speedup", 1.15),
    ("exp14_kernels", "sweep_cut", 2.0),
]


def _rows(path: str) -> dict[str, str]:
    with open(path) as f:
        doc = json.load(f)
    return {row.get("name"): row.get("derived", "") for row in doc.get("rows", [])}


def metric_value(rows: dict[str, str], row: str, metric: str) -> Optional[float]:
    derived = rows.get(row)
    if derived is None:
        return None
    # scientific notation included: kernel rows carry allclose_err=1.19e-07
    m = re.search(rf"{metric}=([0-9]+(?:\.[0-9]+)?(?:[eE][+-]?[0-9]+)?)", derived)
    return float(m.group(1)) if m else None


def check_gate(gate: Gate, baseline: dict, fresh: dict, tolerance: float) -> Optional[str]:
    """None = pass/skip; a string = the failure message."""
    new = metric_value(fresh, gate.row, gate.metric)
    if new is None:
        return f"{gate.row}.{gate.metric}: missing from the fresh run"
    old = metric_value(baseline, gate.row, gate.metric)
    if old is None:
        print(f"{gate.row}.{gate.metric}: no baseline yet -> SKIPPED (fresh={new:g})")
        return None
    if gate.tolerance is not None:
        tolerance = gate.tolerance
    if gate.higher_is_better:
        bound = old * (1.0 - tolerance)
        ok = new >= bound
        rel = "floor"
    else:
        bound = old * (1.0 + tolerance)
        ok = new <= bound
        rel = "ceiling"
    verdict = "OK" if ok else "REGRESSION"
    print(
        f"{gate.row}.{gate.metric}: baseline={old:g} fresh={new:g} "
        f"{rel}={bound:g} (tolerance {tolerance:.0%}) -> {verdict}"
    )
    return None if ok else f"{gate.row}.{gate.metric} regressed: {new:g} vs {rel} {bound:g}"


def check_hard_zero(fresh: dict) -> list[str]:
    failures = []
    for row, metric in HARD_ZERO:
        val = metric_value(fresh, row, metric)
        if val is None:
            failures.append(f"{row}.{metric}: missing from the fresh run")
        elif val != 0:
            failures.append(f"{row}.{metric} must be 0, got {val:g}")
        else:
            print(f"{row}.{metric}: 0 -> OK")
    return failures


def check_hard_max(fresh: dict) -> list[str]:
    failures = []
    for row, metric, ceiling in HARD_MAX:
        val = metric_value(fresh, row, metric)
        if val is None:
            failures.append(f"{row}.{metric}: missing from the fresh run")
        elif val > ceiling:
            failures.append(f"{row}.{metric} must be <= {ceiling:g}, got {val:g}")
        else:
            print(f"{row}.{metric}: {val:g} <= {ceiling:g} -> OK")
    return failures


def check_hard_min(fresh: dict) -> list[str]:
    failures = []
    for row, metric, floor in HARD_MIN:
        val = metric_value(fresh, row, metric)
        if val is None:
            failures.append(f"{row}.{metric}: missing from the fresh run")
        elif val < floor:
            failures.append(f"{row}.{metric} must be >= {floor:g}, got {val:g}")
        else:
            print(f"{row}.{metric}: {val:g} >= {floor:g} -> OK")
    return failures


def main(argv: list[str]) -> int:
    if len(argv) < 2:
        print(__doc__)
        return 2
    baseline_path, fresh_path = argv[0], argv[1]
    tolerance = float(argv[2]) if len(argv) > 2 else DEFAULT_TOLERANCE
    baseline, fresh = _rows(baseline_path), _rows(fresh_path)
    failures = [
        msg
        for gate in GATES
        if (msg := check_gate(gate, baseline, fresh, tolerance)) is not None
    ]
    failures += check_hard_zero(fresh)
    failures += check_hard_max(fresh)
    failures += check_hard_min(fresh)
    for msg in failures:
        print(f"FAIL: {msg}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
