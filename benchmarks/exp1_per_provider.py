"""Experiment 1: per-provider weak/strong scaling of OVH, TH, TPT (paper §5.1).

Paper protocol: 4k/8k/16k noop tasks on 4/8/16 vCPUs per provider, MCPP and
SCPP.  Claims validated:
  * OVH dominated by #tasks/#pods, invariant across providers & vCPUs,
  * SCPP OVH ~ +46% vs MCPP (per-pod serialization I/O),
  * MCPP TH ~ +44% over SCPP,
  * TPT >> OVH (platform overheads dominate the broker's).
"""
from __future__ import annotations

from repro.core import Task

from benchmarks.common import CLOUDS, cloud_provider, make_broker, print_rows, write_csv


def run(n_tasks_list=(500, 1000, 2000), vcpus_list=(4, 8, 16), pod_store="disk",
        providers=CLOUDS, tasks_per_pod=64, verbose=True) -> list[dict]:
    rows = []
    for provider in providers:
        for vcpus in vcpus_list:
            for n_tasks in n_tasks_list:
                for model in ("mcpp", "scpp"):
                    h = make_broker(pod_store=pod_store)
                    h.register_provider(cloud_provider(provider, vcpus=vcpus))
                    tasks = [Task(kind="noop") for _ in range(n_tasks)]
                    sub = h.submit(tasks, partitioning=model, tasks_per_pod=tasks_per_pod)
                    sub.wait(timeout=600)
                    m = sub.metrics()
                    rows.append({
                        "exp": "exp1", "provider": provider, "vcpus": vcpus,
                        "n_tasks": n_tasks, "model": model, "pod_store": pod_store,
                        **m.row(),
                    })
                    h.shutdown(wait=False)
    write_csv(f"exp1_per_provider_{pod_store}", rows)
    if verbose:
        print_rows(rows[-4:])
    return rows


def main(full: bool = False):
    sizes = (4000, 8000, 16000) if full else (500, 1000, 2000)
    return run(n_tasks_list=sizes)


if __name__ == "__main__":
    import sys

    main(full="--full" in sys.argv)
