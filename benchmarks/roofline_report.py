"""Roofline report: aggregates artifacts/dryrun/*.json into the §Roofline
table (every baselined (arch x shape) cell on the single-pod mesh), plus
the autotuner's roofline-predicted Pallas kernel configs when the dry run
saved them (launch/dryrun.py kernel_report) — chosen block config next to
predicted arithmetic intensity, so model-vs-measured drift is one table.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import write_csv

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_records(mesh: str = "16x16", strategy: str = "default") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") == mesh and path.endswith(f"__{strategy}.json"):
            out.append(rec)
    return out


def table(records: list[dict]) -> list[dict]:
    rows = []
    for rec in records:
        rl = rec["roofline"]
        rows.append({
            "arch": rec["arch"],
            "shape": rec["shape"],
            "strategy": rec["strategy"],
            "chips": rl["chips"],
            "t_compute_s": rl["t_compute_s"],
            "t_memory_s": rl["t_memory_s"],
            "t_collective_s": rl["t_collective_s"],
            "t_memory_est_s": rl["t_memory_est_s"],
            "bottleneck": rl["bottleneck"],
            "bottleneck_est": rl["bottleneck_est"],
            "model_flops": rl["model_flops"],
            "useful_flops_frac": rl["useful_flops_frac"],
            "mfu_upper_bound": rl["mfu_upper_bound"],
            "mfu_est": rl["mfu_est"],
            "temp_bytes_per_chip": rec["memory_analysis"].get("temp_size_in_bytes"),
            "arg_bytes_per_chip": rec["memory_analysis"].get("argument_size_in_bytes"),
            "compile_s": rec["compile_s"],
        })
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def markdown(rows: list[dict]) -> str:
    cols = ["arch", "shape", "t_compute_s", "t_memory_est_s", "t_collective_s",
            "bottleneck_est", "useful_flops_frac", "mfu_est"]
    lines = ["| " + " | ".join(cols) + " |", "|" + "---|" * len(cols)]
    for r in rows:
        lines.append("| " + " | ".join(str(r[c]) for c in cols) + " |")
    return "\n".join(lines)


def kernel_predictions() -> list[dict]:
    """Rows saved by ``launch/dryrun.py kernel_report`` (empty when the dry
    run has not been re-run since the autotuner landed)."""
    path = os.path.join(ARTIFACT_DIR, "kernels__predicted.json")
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f).get("rows", [])


def main(full: bool = False):
    rows = table(load_records())
    write_csv("roofline_16x16", rows)
    print(f"roofline cells baselined: {len(rows)}")
    for r in rows:
        print(f"  {r['arch']:22s} {r['shape']:12s} bottleneck={r['bottleneck_est']:10s} "
              f"mfu_est={r['mfu_est']}")
    krows = kernel_predictions()
    if krows:
        write_csv("roofline_kernels_predicted", krows)
        print(f"kernel configs predicted (roofline autotuner): {len(krows)}")
        for r in krows:
            print(f"  {r['kernel']:18s} {r['tier']:5s} config={r['config']:28s} "
                  f"intensity={r['intensity_flops_per_byte']}")
    return rows


if __name__ == "__main__":
    main()
