"""Experiment 8: data-aware staging — locality-aware vs locality-blind
placement under shared inputs at 4 sites.

The staging subsystem (core/staging.py) makes cross-platform data movement a
modeled, chargeable cost: datasets have sizes and replicas, links have
per-platform-pair bandwidth/latency, and the streaming dispatcher stages a
task's inputs to its placement site before dispatch.  This experiment
measures what placement does with that model:

  blind  - round_robin: ignores where bytes live; every stage of a chain
           lands wherever the rotation points, so inter-stage artifacts and
           the shared input shards are re-pulled across sites all run long.
  aware  - data_gravity: charges cold reads their modeled transfer time, so
           chains stay where their bytes already are and each shared shard
           is pulled to (approximately) one site once.

Workload: W chain workflows (3 sleep stages each) over S shared input
shards (1 GB each, pinned in the shared store).  Stage outputs are declared
dataset footprints (512/512/64 MB), so movement is entirely
placement-driven.  Runs on a VirtualClock: transfers and compute are
modeled seconds, the whole sweep takes real milliseconds, and byte counts
are exact.

Measured per arm: mb_moved, cache_hits/cold_reads, transfer_wait_s,
virtual makespan.  Acceptance (ISSUE 4): aware moves >= 30% fewer MB than
blind at 4 sites with non-trivially shared inputs.
"""
from __future__ import annotations

from repro.core import Hydra, Task, Workflow, WorkflowManager
from repro.runtime.clock import virtual_time

from benchmarks.common import print_rows, write_csv
from repro.core.provider import ProviderSpec

N_SITES = 4
SHARD_MB = 1024.0
STAGE_OUT_MB = (512.0, 512.0, 64.0)
STAGE_SLEEP_S = 2.0


def _providers() -> list[ProviderSpec]:
    """Three clouds + one HPC system: the paper's 4-site heterogeneous
    topology, with the cloud<->HPC link as the narrow waist."""
    return [
        ProviderSpec(name="jet2", platform="cloud", concurrency=4),
        ProviderSpec(name="chi", platform="cloud", concurrency=4),
        ProviderSpec(name="aws", platform="cloud", concurrency=4),
        ProviderSpec(name="bridges2", platform="hpc", connector="pilot", concurrency=4),
    ]


def _workflows(n_instances: int, n_shards: int) -> list[Workflow]:
    wfs = []
    for i in range(n_instances):
        shard = f"exp8/shard-{i % n_shards}"
        base = f"exp8/w{i:04d}"
        wf = Workflow(name=f"stage8.{i:04d}")
        t1 = wf.add(
            Task(
                kind="sleep",
                duration=STAGE_SLEEP_S,
                inputs=[shard],
                outputs={f"{base}/a": STAGE_OUT_MB[0]},
            )
        )
        t2 = wf.add(
            Task(
                kind="sleep",
                duration=STAGE_SLEEP_S,
                inputs=[f"{base}/a"],
                outputs={f"{base}/b": STAGE_OUT_MB[1]},
            ),
            deps=[t1],
        )
        wf.add(
            Task(
                kind="sleep",
                duration=STAGE_SLEEP_S,
                inputs=[f"{base}/b", shard],
                outputs={f"{base}/c": STAGE_OUT_MB[2]},
            ),
            deps=[t2],
        )
        wfs.append(wf)
    return wfs


def _run_arm(policy: str, n_instances: int, n_shards: int, seed: int = 0) -> dict:
    with virtual_time() as clock:
        h = Hydra(
            pod_store="memory",
            policy=policy,
            streaming=True,
            batch_window=0.001,
            tasks_per_pod=16,
            staging_seed=seed,
        )
        for spec in _providers():
            h.register_provider(spec)
        for k in range(n_shards):
            h.staging.registry.add(
                f"exp8/shard-{k}", SHARD_MB, sites=["shared"], pinned=True
            )
        wfs = _workflows(n_instances, n_shards)
        t0 = clock.now()
        WorkflowManager(h).run(wfs, timeout=3600)
        makespan = clock.now() - t0
        stats = h.staging_stats()
        row = {
            "mode": "aware" if policy == "data_gravity" else "blind",
            "policy": policy,
            "n_instances": n_instances,
            "n_shards": n_shards,
            "n_sites": N_SITES,
            "all_done": all(w.done and not w.failed for w in wfs),
            "mb_moved": stats["mb_moved"],
            "transfers": stats["transfers"],
            "cache_hits": stats["cache_hits"],
            "cold_reads": stats["cold_reads"],
            "transfer_wait_s": stats["transfer_wait_s"],
            "makespan_s": round(makespan, 3),
        }
        h.shutdown(wait=True)
    return row


def run(n_instances: int, n_shards: int = 4, verbose: bool = True) -> list[dict]:
    blind = _run_arm("round_robin", n_instances, n_shards)
    aware = _run_arm("data_gravity", n_instances, n_shards)
    reduction = 1.0 - aware["mb_moved"] / max(blind["mb_moved"], 1e-9)
    speedup = blind["makespan_s"] / max(aware["makespan_s"], 1e-9)
    for row in (blind, aware):
        row["bytes_reduction"] = round(reduction, 4)
        row["makespan_speedup"] = round(speedup, 4)
    rows = [blind, aware]
    write_csv("exp8_staging", rows)
    if verbose:
        print_rows(rows)
    return rows


def main(full: bool = False, smoke: bool = False):
    if smoke:
        return run(n_instances=12, n_shards=3)
    if full:
        return run(n_instances=160)
    return run(n_instances=48)


if __name__ == "__main__":
    import sys

    main(full="--full" in sys.argv, smoke="--smoke" in sys.argv)
