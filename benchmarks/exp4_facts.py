"""Experiment 4: FACTS workflow strong/weak scaling (paper §5.4).

Runs N concurrent FACTS instances (pre -> fit -> project -> post) across a
cloud pool + an HPC pilot, measuring workflow TTX/makespan and broker OVH.
Claims:
  * broker OVH invariant across workload/resource types and negligible vs
    the workflow makespan,
  * weak scaling close to ideal;
  * strong scaling sublinear at high concurrency (platform overheads).
"""
from __future__ import annotations

import time

from repro.core import WorkflowManager

from benchmarks.common import cloud_provider, hpc_provider, make_broker, print_rows, write_csv
from repro.facts.workflow import make_workflow


def run(n_workflows_list=(8, 16, 32), cores_list=(4, 8, 16), pod_store="disk",
        verbose=True, n_samples=150_000) -> list[dict]:
    # n_samples=150k gives each projection stage ~0.5-1 s of real MC compute
    # (the paper's stages are ~core-minutes; same OVH-vs-TTX regime)
    rows = []
    for n_wf in n_workflows_list:
        for cores in cores_list:
            h = make_broker(pod_store=pod_store, policy="load_aware")
            h.register_provider(cloud_provider("jet2", vcpus=cores))
            h.register_provider(cloud_provider("aws", vcpus=cores))
            h.register_provider(hpc_provider(cores=cores))
            wfm = WorkflowManager(h)
            wfs = [make_workflow(h.data, i, n_samples=n_samples) for i in range(n_wf)]
            t0 = time.perf_counter()
            wfm.run(wfs)
            ttx = time.perf_counter() - t0
            # broker-side work across all frontier submissions: bind +
            # partition + serialize phases.  (The submit phase is excluded:
            # under incremental workflow submission it blocks on the shared
            # dispatch executor, i.e. it overlaps task *execution* on this
            # single-core host and would double-count platform time.)
            # phase_totals() includes submissions the broker already pruned
            # (resolved submissions retire their metrics, bounding memory).
            phases = h.phase_totals()
            ovh = sum(v for k, v in phases.items() if k != "submit")
            rows.append({
                "exp": "exp4", "n_workflows": n_wf, "cores_per_provider": cores,
                "ttx_s": round(ttx, 4), "ovh_s": round(ovh, 4),
                "ovh_frac": round(ovh / max(ttx, 1e-9), 5),
                "all_done": all(w.done and not w.failed for w in wfs),
                "mean_makespan_s": round(
                    sum(w.makespan() or 0 for w in wfs) / max(len(wfs), 1), 4
                ),
            })
            h.shutdown(wait=False)
    write_csv(f"exp4_facts_{pod_store}", rows)
    if verbose:
        print_rows(rows)
    return rows


def main(full: bool = False, smoke: bool = False):
    if smoke:
        # CI lane: ONE cell with light MC stages.  The old smoke ran the
        # full 3x3 sweep at 150k samples (~18 s mean ttx per cell) and
        # dominated the whole smoke suite's budget; the OVH-vs-TTX claim
        # only needs a representative cell here — the sweep stays in the
        # default/full lanes.
        return run(n_workflows_list=(6,), cores_list=(4,), n_samples=15_000)
    if full:
        return run(n_workflows_list=(50, 100, 200, 400, 800), cores_list=(16,))
    return run()


if __name__ == "__main__":
    import sys

    main(full="--full" in sys.argv)
