"""Experiment 9: broker-side dispatch throughput (§Perf, scheduler core).

The paper claims near-constant broker overhead as tasks and platforms scale
(§5.4, §6).  This experiment measures exactly the broker-side cost — the
streaming dispatcher's bind/partition/serialize/deliver loop driven by the
CapacityLedger (core/ledger.py), the indexed-eligibility/heap policies
(core/policy.py), and event-driven wakeups (core/dispatcher.py) — using
zero-work tasks on a virtual clock, so platform execution time and clock
advancement contribute nothing and tasks/s IS dispatch throughput.

Two arms:

  scaling  - fixed task count, provider fleet swept 16 -> 256 (smoke:
             8 -> 32), locality-blind load_aware.  The paper-shaped claim:
             per-task dispatch cost stays flat (+-20%) as the fleet grows
             16x, because eligibility is indexed, placement pops a heap,
             and capacity reads are O(1) counters instead of fleet scans.
  data     - the headline: data-aware dispatch (data_gravity) of up to
             100k single-input tasks across 256 providers.  Pre-PR this
             was the worst hot path — one modeled staging query per task
             PER provider under the policy lock; now the gate prices each
             (inputs-signature, targets) once per batch
             (StagingService.transfer_cost_many + Policy.bulk_scope).

Measured pre-PR core (commit 0b2b9d7, this machine, min-of-2/3):
  scaling 256 providers: ~505 us/task (vs ~134 at 16: 3.8x growth)
  data    10k x 256:     227 tasks/s (4413 us/task)
Post-PR acceptance: data arm >= 5x pre-PR tasks/s; scaling arm flat +-20%.
"""
from __future__ import annotations

import time

from repro.core import Hydra, ProviderSpec, Task
from repro.runtime.clock import virtual_time

from benchmarks.common import print_rows, write_csv

N_SHARDS = 4  # distinct input signatures in the data arm


def _drain(tasks, timeout_s: float) -> None:
    deadline = time.monotonic() + timeout_s
    for t in tasks:
        remaining = deadline - time.monotonic()
        if remaining <= 0:
            raise TimeoutError(f"exp9: drain exceeded {timeout_s:.0f}s deadline")
        t.result(timeout=remaining)


def _run_once(
    n_tasks: int,
    n_providers: int,
    policy: str,
    max_batch: int,
    tasks_per_pod: int,
    with_inputs: bool,
    timeout_s: float = 900.0,
) -> float:
    with virtual_time():
        h = Hydra(
            pod_store="memory",
            streaming=True,
            batch_window=0.0,
            max_batch=max_batch,
            tasks_per_pod=tasks_per_pod,
            policy=policy,
        )
        for i in range(n_providers):
            h.register_provider(ProviderSpec(name=f"p{i}", concurrency=4))
        if with_inputs:
            for s in range(N_SHARDS):
                h.staging.registry.add(f"shard{s}", 256.0, sites=["p0"])
            tasks = [
                Task(kind="noop", inputs=[f"shard{i % N_SHARDS}"])
                for i in range(n_tasks)
            ]
        else:
            tasks = [Task(kind="noop") for _ in range(n_tasks)]
        t0 = time.perf_counter()
        h.dispatch(tasks)
        _drain(tasks, timeout_s)
        dt = time.perf_counter() - t0
        h.shutdown(wait=False)
    return dt


def _best_of(n_reps: int, *args, **kw) -> float:
    # min-of-N: dispatch cost is a floor measurement and this is a noisy
    # shared machine — the fastest rep is the least-perturbed one
    return min(_run_once(*args, **kw) for _ in range(max(1, n_reps)))


def run(
    scaling_tasks: int = 20_000,
    scaling_providers=(16, 64, 256),
    data_tasks: int = 100_000,
    data_providers: int = 256,
    reps: int = 3,
    verbose: bool = True,
) -> list[dict]:
    rows: list[dict] = []

    # fixed pod shape (tasks_per_pod=4) across the whole sweep: what must
    # stay flat is the SCHEDULER's per-task cost as the fleet grows 16x —
    # letting pod size shrink from 64 tasks (16 providers) to 4 (256) would
    # fold per-pod serialization/delivery constants into the comparison
    for n_prov in scaling_providers:
        dt = _best_of(reps, scaling_tasks, n_prov, "load_aware", 1024, 4, False)
        rows.append(
            {
                "exp": "exp9",
                "mode": "scaling",
                "n_tasks": scaling_tasks,
                "n_providers": n_prov,
                "wall_s": round(dt, 3),
                "dispatch_tasks_per_s": round(scaling_tasks / dt, 1),
                "us_per_task": round(dt / scaling_tasks * 1e6, 1),
            }
        )

    base = next(r for r in rows if r["n_providers"] == scaling_providers[0])
    for r in rows:
        r["cost_vs_smallest_fleet"] = round(r["us_per_task"] / base["us_per_task"], 3)

    dt = _best_of(reps, data_tasks, data_providers, "data_gravity", 2048, 8, True)
    rows.append(
        {
            "exp": "exp9",
            "mode": "data",
            "n_tasks": data_tasks,
            "n_providers": data_providers,
            "wall_s": round(dt, 3),
            "dispatch_tasks_per_s": round(data_tasks / dt, 1),
            "us_per_task": round(dt / data_tasks * 1e6, 1),
            "cost_vs_smallest_fleet": None,
        }
    )

    write_csv("exp9_sched", rows)
    if verbose:
        print_rows(rows)
    return rows


def main(full: bool = False, smoke: bool = False) -> list[dict]:
    if smoke:
        # CI-sized: small fleets/counts, min-of-2 — the smoke row feeds the
        # dispatch-throughput regression gate (benchmarks/check_bench.py),
        # and taking the best rep biases the FRESH side of that comparison
        # against load-noise false alarms (the committed baseline should be
        # produced under load, i.e. on the low side, for the same reason)
        return run(
            scaling_tasks=2_000,
            scaling_providers=(8, 32),
            data_tasks=2_000,
            data_providers=32,
            reps=2,
        )
    if full:
        return run()
    return run(scaling_tasks=10_000, data_tasks=20_000, reps=2)


if __name__ == "__main__":
    import sys

    main(full="--full" in sys.argv, smoke="--smoke" in sys.argv)
