"""Experiment 6: streaming dispatcher vs frontier-mode workflow execution.

The paper's Exp 4 scales FACTS-shaped DAG workloads to 800 concurrent
instances and claims near-constant broker overhead (§5.4, §6).  Frontier
mode works against that claim: every readiness event is a full
bind/partition/serialize/dispatch round, so pipeline rounds (and pods,
mostly single-task) grow with DAG depth x instance count.  The streaming
dispatcher (core/dispatcher.py) coalesces readiness events across ALL
instances into micro-batched, late-bound pods.

Measured here, per instance count (100/400/800 by default):

  n_submits  - full broker pipeline rounds issued
  n_pods     - pods serialized + dispatched
  makespan_s - wall-clock end-to-end for the whole instance set
  pod_ratio  - frontier pods / streaming pods (acceptance: >= 1.5 at 800)

Tasks are noop (the paper's overhead-isolation instrument) in the FACTS
4-stage chain shape, so the deltas below are pure broker-side behaviour.
"""
from __future__ import annotations

import time

from repro.core import Hydra, Task, Workflow, WorkflowManager

from benchmarks.common import cloud_provider, hpc_provider, print_rows, write_csv


def facts_shaped_workflows(n_instances: int, stages: int = 4) -> list[Workflow]:
    """FACTS DAG shape (pre -> fit -> project -> post) with noop stages."""
    wfs = []
    for i in range(n_instances):
        wf = Workflow(name=f"facts6.{i:05d}")
        prev = None
        for _ in range(stages):
            prev = wf.add(Task(kind="noop"), deps=[prev] if prev else None)
        wfs.append(wf)
    return wfs


def _run_mode(streaming: bool, n_instances: int) -> dict:
    h = Hydra(
        pod_store="memory",
        policy="round_robin",
        tasks_per_pod=64,
        streaming=streaming,
        batch_window=0.002,
        max_batch=512,
    )
    h.register_provider(cloud_provider("jet2", vcpus=16))
    h.register_provider(cloud_provider("aws", vcpus=16))
    h.register_provider(hpc_provider(cores=16))
    wfm = WorkflowManager(h)
    wfs = facts_shaped_workflows(n_instances)
    t0 = time.perf_counter()
    wfm.run(wfs, timeout=600)
    makespan = time.perf_counter() - t0
    if streaming:
        h.dispatcher().drain(timeout=10)
    stats = h.stream_stats()
    row = {
        "mode": "streaming" if streaming else "frontier",
        "n_instances": n_instances,
        "n_tasks": sum(len(w.tasks) for w in wfs),
        "n_submits": stats["n_submits"],
        "n_pods": stats["n_pods"],
        "makespan_s": round(makespan, 4),
        "all_done": all(w.done and not w.failed for w in wfs),
        "mean_batch_size": stats.get("mean_batch_size", 1.0),
    }
    h.shutdown(wait=True)  # join worker threads: no bleed into the next mode
    return row


def run(n_instances_list=(100, 400, 800), verbose=True) -> list[dict]:
    rows = []
    for n in n_instances_list:
        frontier = _run_mode(streaming=False, n_instances=n)
        streaming = _run_mode(streaming=True, n_instances=n)
        for row in (frontier, streaming):
            row["submit_ratio"] = round(frontier["n_submits"] / max(streaming["n_submits"], 1), 2)
            row["pod_ratio"] = round(frontier["n_pods"] / max(streaming["n_pods"], 1), 2)
            rows.append(row)
    write_csv("exp6_streaming", rows)
    if verbose:
        print_rows(rows)
    return rows


def main(full: bool = False):
    if full:
        return run(n_instances_list=(100, 400, 800))
    return run(n_instances_list=(50, 100))


if __name__ == "__main__":
    import sys

    main(full="--full" in sys.argv)
