"""Experiment 3B: heterogeneous tasks on heterogeneous nodes (paper §5.3).

Tasks with mixed durations (paper: 1-10 s, scaled 100x down here) and mixed
resource requests (1-4 CPUs, 0-8 accels) on 2/4/6-node pools.  Claims:
  * OVH rises only ~5% above 2 nodes and flattens,
  * TH essentially invariant in node count,
  * TPT scales with nodes (sublinearly at the top end).
"""
from __future__ import annotations

import numpy as np

from repro.core import Resources, Task

from benchmarks.common import cloud_provider, hpc_provider, make_broker, print_rows, write_csv


def heterogeneous_workload(n_tasks: int, seed: int = 0, dur_scale: float = 0.01) -> list[Task]:
    rng = np.random.default_rng(seed)
    tasks = []
    for i in range(n_tasks):
        tasks.append(
            Task(
                kind="sleep",
                duration=float(rng.uniform(1, 10)) * dur_scale,
                resources=Resources(
                    cpus=int(rng.integers(1, 5)),
                    accels=int(rng.choice([0, 0, 1, 2, 4, 8])),
                    memory_mb=int(rng.choice([256, 512, 1024])),
                ),
            )
        )
    return tasks


def run(n_tasks=1024, nodes_list=(2, 4, 6), pod_store="disk", verbose=True) -> list[dict]:
    rows = []
    for nodes in nodes_list:
        h = make_broker(pod_store=pod_store, policy="load_aware")
        spec = cloud_provider("jet2", vcpus=4 * nodes)
        spec.n_nodes = nodes
        h.register_provider(spec)
        hspec = hpc_provider(cores=4 * nodes)
        hspec.n_nodes = nodes
        h.register_provider(hspec)
        tasks = heterogeneous_workload(n_tasks)
        sub = h.submit(tasks, partitioning="binpack")
        sub.wait(timeout=600)
        m = sub.metrics()
        rows.append({
            "exp": "exp3b", "nodes": nodes, "n_tasks": n_tasks,
            "model": "binpack", "pod_store": pod_store, **m.row(),
        })
        h.shutdown(wait=False)
    write_csv(f"exp3b_heterogeneous_{pod_store}", rows)
    if verbose:
        print_rows(rows)
    return rows


def main(full: bool = False):
    n = 10240 if full else 1024
    return run(n_tasks=n)


if __name__ == "__main__":
    import sys

    main(full="--full" in sys.argv)
